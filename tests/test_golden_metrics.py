"""Golden-metrics regression: one seeded round per engine variant, plus
one short buffered-async run per async variant.

Every stage combination from the engine grid (sampler x link x executor x
aggregator) runs ONE deterministic round and is pinned against the
checked-in goldens in ``tests/goldens/engine_goldens.json``:

* ``wire_bytes`` — exact integer equality (any cohort/link/payload drift
  fails immediately);
* ``local_loss`` and per-leaf ``(mean, l2)`` fingerprints of the new
  server model — tight relative tolerance (2e-5). A semantic regression
  (key-split reorder, stage rewiring, rounding-mode confusion, changed
  sampler) shifts these by orders of magnitude more; last-ULP platform
  noise (different SIMD widths re-tiling XLA:CPU's GEMMs) sits ~100x
  below it. Numeric drift in any stage therefore fails THIS fast unit
  test instead of surfacing as a slow-lane convergence flake.

Regenerating the goldens (after an INTENDED numerics change — review the
diff of the JSON, it is the contract):

    PYTHONPATH=src python tests/test_golden_metrics.py --regen
"""
import json
import os

# honor REPRO_VIRTUAL_DEVICES on DIRECT runs too (--regen of the 2D-mesh
# variants): the flag must reach XLA before jax initializes. Under pytest
# the conftest has already applied it — the guard keeps this idempotent.
_want = os.environ.get("REPRO_VIRTUAL_DEVICES", "")
if _want.isdigit() and int(_want) > 1 and (
        "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_want}"
    ).strip()

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core.async_engine import AsyncConfig, BufferedAsyncEngine
from repro.core.codec import CodecSchedule
from repro.core.engine import FedConfig, RoundEngine
from repro.core.qat import (
    DISABLED,
    QATConfig,
    clip_value_mask,
    weight_decay_mask,
)
from repro.core.fp8 import E5M2
from repro.core.server_opt import ServerOptConfig
from repro.data import partition_iid, synthetic_classification
from repro.models import small

GOLDEN_PATH = os.path.join(os.path.dirname(__file__), "goldens",
                           "engine_goldens.json")

_BASE = dict(n_clients=6, participation=0.5, local_steps=2, batch_size=8)

# id -> FedConfig kwargs beyond _BASE; one variant per engine knob value
VARIANTS = {
    "uniform_rand_mean": dict(comm_mode="rand", qat=QATConfig()),
    "weighted_rand_mean": dict(comm_mode="rand", qat=QATConfig(),
                               sampler="weighted"),
    "fixed_det_mean": dict(comm_mode="det", qat=QATConfig(),
                           sampler="fixed"),
    "uniform_fp32_mean": dict(comm_mode="none", qat=DISABLED),
    "hybrid_rand_mean": dict(comm_mode="rand", qat=QATConfig(),
                             up_fmt=E5M2),
    "fp32down_fp8up_mean": dict(comm_mode="rand", qat=QATConfig(),
                                down_mode="none"),
    "chunked_rand_mean": dict(comm_mode="rand", qat=QATConfig(), chunk=2),
    "uniform_rand_fedavgm": dict(comm_mode="rand", qat=QATConfig(),
                                 aggregator="fedavgm", server_lr=1.0,
                                 server_momentum=0.9),
    "uniform_rand_fedadam": dict(comm_mode="rand", qat=QATConfig(),
                                 aggregator="fedadam", server_lr=0.05),
    "uniform_rand_serveropt": dict(
        comm_mode="rand", qat=QATConfig(),
        server_opt=ServerOptConfig(enabled=True, gd_steps=2, lr=0.1,
                                   n_grid=5),
    ),
    # --- codec-API variants (ISSUE 5): sub-byte / delta / schedule ------
    "fp4_rand_mean": dict(comm_mode="rand", qat=QATConfig(),
                          down_codec="fp4", up_codec="fp4"),
    "fp4_e3m0_det_mean": dict(comm_mode="rand", qat=QATConfig(),
                              down_codec="fp4_e3m0_det",
                              up_codec="fp4_e3m0_det"),
    "delta_up_mean": dict(comm_mode="rand", qat=QATConfig(),
                          up_codec="delta:e4m3"),
    "sched_e5m2_fp4_mean": dict(
        comm_mode="rand", qat=QATConfig(),
        codec_schedule=CodecSchedule(("e5m2", "fp4"), (1,)),
    ),
    # --- compression-research variants (ISSUE 10): EF + entropy wire ----
    # wire_bytes of the rans variants pins the TRACED (entropy-coded)
    # ledger — data-dependent but deterministic in the seed
    "ef_fp4_det_mean": dict(comm_mode="rand", qat=QATConfig(),
                            up_codec="ef:fp4_e2m1_det"),
    "rans_delta_fp4_mean": dict(comm_mode="rand", qat=QATConfig(),
                                down_codec="rans:fp4_e2m1",
                                up_codec="rans:delta:fp4_e2m1"),
    "ef_rans_fp4_det_mean": dict(comm_mode="rand", qat=QATConfig(),
                                 down_codec="rans:fp4_e2m1",
                                 up_codec="ef:rans:fp4_e2m1_det"),
    # --- scaling-policy variants (ISSUE 8): delayed / frozen wires ------
    "delayed_wire_mean": dict(comm_mode="rand", qat=QATConfig(),
                              down_scaling="delayed:4",
                              up_scaling="delayed:4:1"),
    "frozen_down_mean": dict(comm_mode="rand", qat=QATConfig(),
                             down_scaling="frozen"),
    # --- 2D federated mesh variants (ISSUE 7): clients x fsdp -----------
    # ``mesh2d`` resolves lazily to make_fed_mesh(C, F) + model_axis so
    # importing this module never touches device state; the test skips
    # when fewer than C*F devices exist (run the multi-device lane:
    # REPRO_VIRTUAL_DEVICES=8). det wires keep the pins insensitive to
    # how GSPMD places the legacy (non-partitionable) threefry.
    "fed2d_2x4_det_mean": dict(comm_mode="det", qat=QATConfig(),
                               mesh2d=(2, 4)),
    "fed2d_2x4_fp4_fedavgm": dict(comm_mode="det", qat=QATConfig(),
                                  down_codec="fp4_det", up_codec="fp4_det",
                                  aggregator="fedavgm", server_lr=1.0,
                                  server_momentum=0.9, mesh2d=(2, 4)),
    "fed2d_4x2_det_mean": dict(comm_mode="det", qat=QATConfig(),
                               participation=1.0, mesh2d=(4, 2)),
}


def _variant_devices(variant: str) -> int:
    c, f = VARIANTS[variant].get("mesh2d", (1, 1))
    return c * f


# buffered-async variants (ISSUE 6): buffer size x staleness discount x
# momentum x delta-coded uplink, each pinned as (exact cumulative bytes,
# loss, param fingerprints) of a short deterministic event-loop run
ASYNC_VARIANTS = {
    "k2_plain": dict(acfg=dict(buffer_size=2, staleness_alpha=0.0)),
    "k4_stale1": dict(acfg=dict(buffer_size=4, staleness_alpha=1.0)),
    "k2_momentum": dict(acfg=dict(buffer_size=2, staleness_alpha=0.5,
                                  server_momentum=0.9)),
    "k2_delta_up": dict(acfg=dict(buffer_size=2, staleness_alpha=0.5),
                        cfg=dict(up_codec="delta:e4m3")),
}


def _leaf_fingerprints(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves = {}
    for path, leaf in flat:
        name = ".".join(str(getattr(p, "key", p)) for p in path)
        arr = np.asarray(leaf, np.float64)
        leaves[name] = [float(arr.mean()), float(np.linalg.norm(arr))]
    return leaves


def _async_round_metrics(variant: str) -> dict:
    params, loss, opt, (cx, cy, nk) = _setup()
    spec = ASYNC_VARIANTS[variant]
    cfg = FedConfig(**_BASE, comm_mode="rand", qat=QATConfig(),
                    **spec.get("cfg", {}))
    eng = BufferedAsyncEngine(loss, opt, cfg,
                              AsyncConfig(concurrency=4, **spec["acfg"]))
    state, hist = eng.run(params, cx, cy, jax.random.PRNGKey(42), folds=4,
                          eval_every=4)
    return {
        "wire_bytes": hist.cumulative_bytes[-1],
        "local_loss": hist.loss[-1],
        "mean_staleness": hist.mean_staleness[-1],
        "leaves": _leaf_fingerprints(state.params),
    }


def _setup():
    xall, yall = synthetic_classification(0, 900, d=16, n_classes=4)
    cx, cy, nk = partition_iid(xall[:600], yall[:600], k=6, seed=0)
    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=16, n_classes=4)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    return params, loss, opt, (jnp.asarray(cx), jnp.asarray(cy),
                               jnp.asarray(nk))


def _round_metrics(variant: str) -> dict:
    params, loss, opt, data = _setup()
    kw = {**_BASE, **VARIANTS[variant]}
    mesh2d = kw.pop("mesh2d", None)
    if mesh2d is not None:
        from repro.launch.mesh import make_fed_mesh

        kw["mesh"] = make_fed_mesh(*mesh2d)
        kw["model_axis"] = "fsdp"
    cfg = FedConfig(**kw)
    eng = RoundEngine(loss, opt, cfg)
    state, m = jax.jit(eng.round_fn)(eng.init(params), *data,
                                     jax.random.PRNGKey(42))
    return {
        "wire_bytes": int(m["wire_bytes"]),
        "local_loss": float(m["local_loss"]),
        "leaves": _leaf_fingerprints(state.params),
    }


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_golden_metrics(variant):
    need = _variant_devices(variant)
    if need > len(jax.devices()):
        pytest.skip(f"needs {need} devices (REPRO_VIRTUAL_DEVICES={need})")
    with open(GOLDEN_PATH) as f:
        goldens = json.load(f)
    assert variant in goldens["variants"], (
        f"no golden for {variant!r} — regenerate: "
        "PYTHONPATH=src python tests/test_golden_metrics.py --regen"
    )
    want = goldens["variants"][variant]
    got = _round_metrics(variant)
    assert got["wire_bytes"] == want["wire_bytes"], (
        variant, got["wire_bytes"], want["wire_bytes"])
    np.testing.assert_allclose(
        got["local_loss"], want["local_loss"], rtol=2e-5,
        err_msg=f"{variant}: local_loss drifted")
    assert got["leaves"].keys() == want["leaves"].keys(), variant
    for name, (mean, l2) in got["leaves"].items():
        wm, wl = want["leaves"][name]
        np.testing.assert_allclose(
            [mean, l2], [wm, wl], rtol=2e-5, atol=1e-7,
            err_msg=f"{variant}/{name}: params fingerprint drifted "
                    "(intended? regen via tests/test_golden_metrics.py)")


@pytest.mark.parametrize("variant", sorted(ASYNC_VARIANTS))
def test_golden_async_metrics(variant):
    """The buffered-async event loop's trajectory is deterministic in
    (seed, configuration): exact cumulative wire bytes, tight-rtol loss /
    staleness / param fingerprints after 4 folds."""
    with open(GOLDEN_PATH) as f:
        goldens = json.load(f)
    assert variant in goldens.get("async_variants", {}), (
        f"no async golden for {variant!r} — regenerate: "
        "PYTHONPATH=src python tests/test_golden_metrics.py --regen"
    )
    want = goldens["async_variants"][variant]
    got = _async_round_metrics(variant)
    assert got["wire_bytes"] == want["wire_bytes"], (
        variant, got["wire_bytes"], want["wire_bytes"])
    np.testing.assert_allclose(
        got["local_loss"], want["local_loss"], rtol=2e-5,
        err_msg=f"{variant}: local_loss drifted")
    np.testing.assert_allclose(
        got["mean_staleness"], want["mean_staleness"], rtol=1e-9,
        err_msg=f"{variant}: dispatch/fold order drifted")
    assert got["leaves"].keys() == want["leaves"].keys(), variant
    for name, (mean, l2) in got["leaves"].items():
        wm, wl = want["leaves"][name]
        np.testing.assert_allclose(
            [mean, l2], [wm, wl], rtol=2e-5, atol=1e-7,
            err_msg=f"{variant}/{name}: async params fingerprint drifted "
                    "(intended? regen via tests/test_golden_metrics.py)")


def _regen():
    existing = {}
    if os.path.exists(GOLDEN_PATH):
        with open(GOLDEN_PATH) as f:
            existing = json.load(f).get("variants", {})
    variants = {}
    for v in sorted(VARIANTS):
        need = _variant_devices(v)
        if need > len(jax.devices()):
            # keep the checked-in pin rather than silently dropping it;
            # regenerate 2D-mesh variants under REPRO_VIRTUAL_DEVICES=8
            assert v in existing, (
                f"{v} needs {need} devices to regenerate: rerun with "
                f"REPRO_VIRTUAL_DEVICES={need}")
            print(f"kept existing golden for {v} "
                  f"(needs {need} devices, have {len(jax.devices())})")
            variants[v] = existing[v]
            continue
        variants[v] = _round_metrics(v)
    out = {
        "_regen": "PYTHONPATH=src python tests/test_golden_metrics.py --regen",
        "_seed": 42,
        "_jax": jax.__version__,
        "variants": variants,
        "async_variants": {
            v: _async_round_metrics(v) for v in sorted(ASYNC_VARIANTS)
        },
    }
    os.makedirs(os.path.dirname(GOLDEN_PATH), exist_ok=True)
    with open(GOLDEN_PATH, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
        f.write("\n")
    print(f"wrote {len(out['variants'])} sync + "
          f"{len(out['async_variants'])} async goldens to {GOLDEN_PATH}")


if __name__ == "__main__":
    import sys

    if "--regen" in sys.argv:
        _regen()
    else:
        print(__doc__)
