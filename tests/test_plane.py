"""Tiled parameter plane (core.plane): layout, one-launch Q_det kernel
pair, and the two hot paths routed through it (opt_level-1
quantize-params-once, UQ+ server_optimize).

Parity bars (ISSUE 2): plane values AND vjp grads (weights + alphas) match
the per-leaf reference to <= 1e-5 on LeNet and on a stacked
``(L, 1, ..., 1)``-alpha tree; kernel launches are O(1) in n_tensors
(dispatch-count assertions at trace time).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import fp8, plane, qat
from repro.core.qat import QATConfig, alpha_like
from repro.core.server_opt import (
    ServerOptConfig,
    server_optimize,
    server_optimize_reference,
)
from repro.kernels import dispatch, fp8_quant
from repro.launch.steps import (
    quantize_params_once,
    quantize_params_once_per_leaf,
)
from repro.models import small


def _rel_close(got, want, tol=1e-5):
    got = np.asarray(got, np.float64)
    want = np.asarray(want, np.float64)
    scale = max(np.max(np.abs(want)), 1e-6)
    err = np.max(np.abs(got - want)) / scale
    assert err <= tol, f"relative error {err:.3e} > {tol:g}"


def _tree_rel_close(got, want, tol=1e-5):
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(got)[0],
        jax.tree_util.tree_flatten_with_path(want)[0],
    ):
        name = ".".join(qat._key_name(p) for p in path)
        try:
            _rel_close(a, b, tol)
        except AssertionError as e:
            raise AssertionError(f"{name}: {e}") from None


@pytest.fixture(scope="module")
def lenet_params():
    return small.REGISTRY["lenet"][0](jax.random.PRNGKey(0), n_classes=10)


@pytest.fixture(scope="module")
def scanned_params():
    """Reduced tinyllama: scanned blocks with stacked (L, 1, ..., 1) alphas."""
    from repro.models.registry import get_model

    cfg = configs.reduced(configs.get("tinyllama_1_1b"))
    return get_model(cfg).init(jax.random.PRNGKey(0))


def _stacked_tree():
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (8, 16))
    ws = jax.random.normal(jax.random.fold_in(key, 1), (2, 4, 8)) * 0.4
    return {
        "d": {"w": w, "w_qa": alpha_like(w), "b": jnp.zeros((16,))},
        "s": {"w": ws, "w_qa": alpha_like(ws, stacked=True)},
    }


# ---------------------------------------------------------------------------
# layout
# ---------------------------------------------------------------------------


def test_pack_unpack_roundtrip(lenet_params):
    spec = plane.make_plane_spec(lenet_params)
    x2, alphas = plane.pack_tiles(lenet_params, spec)
    assert x2.shape == (spec.n_rows, plane.LANE)
    assert alphas.shape == (spec.n_seg,)
    flat = jax.tree_util.tree_leaves(lenet_params)
    for qi, slot in enumerate(spec.q_slots):
        back = plane.leaf_from_tiles(x2, spec, qi)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(flat[slot]))


def test_stacked_alphas_get_one_segment_per_layer():
    spec = plane.make_plane_spec(_stacked_tree())
    # "d.w" is one segment; "s.w" (L=2 stacked) contributes two
    assert spec.n_seg == 3
    assert spec.leaf_segs == (1, 2)
    # every row maps to exactly one alpha scalar
    assert spec.row_seg.shape == (spec.n_rows,)
    assert spec.row_seg.max() == spec.n_seg - 1
    x2, alphas = plane.pack_tiles(_stacked_tree(), spec)
    col = plane.alpha_column(alphas, spec)
    assert col.shape == (spec.n_rows, 1)


# ---------------------------------------------------------------------------
# fused tiled Q_det kernel pair (interpret mode) vs the jnp oracle
# ---------------------------------------------------------------------------


def test_quant_det_tiles_kernel_matches_jnp():
    x2 = jax.random.normal(jax.random.PRNGKey(0), (37, plane.LANE), jnp.float32)
    a2 = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (37, 1))) * 0.7 + 0.2
    got = fp8_quant.quant_det_tiles(x2, a2, interpret=True)
    want = fp8.quantize_det(x2, a2)
    # bit-for-bit modulo 1-ULP transcendental (log2/exp2) differences
    # between the interpreted kernel body and the jnp chain
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=2e-6, atol=0
    )


def test_quant_det_tiles_bwd_kernel_matches_row_oracle():
    """Backward kernel vs the STE closed form with MATCHED (per-row)
    accumulation order: clip mask to the tiles, clip routing + scale term
    summed per row."""
    x2 = jax.random.normal(jax.random.PRNGKey(2), (37, plane.LANE), jnp.float32)
    a2 = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (37, 1))) * 0.6 + 0.1
    g2 = jax.random.normal(jax.random.PRNGKey(4), x2.shape, jnp.float32)
    gx, ga_row = fp8_quant.quant_det_tiles_bwd(x2, a2, g2, interpret=True)

    b = fp8.exponent_bias(a2)
    inside = (jnp.abs(x2) <= a2).astype(jnp.float32)
    xc = jnp.clip(x2, -a2, a2)
    p = jnp.floor(jnp.log2(jnp.abs(xc)) + b)
    p = jnp.where(p > 1.0, p, 1.0)
    s = jnp.exp2(p - b - 3)
    y = xc / s
    q = jnp.round(y)
    _rel_close(gx, g2 * inside)
    _rel_close(ga_row, jnp.sum(
        g2 * (jnp.sign(x2) * (1.0 - inside) + (q - y) * s / a2),
        axis=1, keepdims=True,
    ))


def test_fake_quant_plane_vjp_is_ste():
    """The rand-plane custom VJP: grads follow the STE closed form built
    from the SAME stochastic forward output."""
    x2 = jax.random.normal(jax.random.PRNGKey(5), (16, plane.LANE), jnp.float32)
    a2 = jnp.full((16, 1), 0.8, jnp.float32)
    g2 = jax.random.normal(jax.random.PRNGKey(6), x2.shape, jnp.float32)
    key2 = jnp.asarray([17, 29], jnp.uint32)
    q, vjp = jax.vjp(
        lambda x, a: dispatch.fake_quant_plane(x, a, key2, fp8.E4M3), x2, a2
    )
    gx, ga = vjp(g2)
    inside = (jnp.abs(x2) <= a2).astype(jnp.float32)
    xc = jnp.clip(x2, -a2, a2)
    _rel_close(gx, g2 * inside)
    _rel_close(ga, jnp.sum(
        g2 * (jnp.sign(x2) * (1.0 - inside) + (q - xc) / a2),
        axis=1, keepdims=True,
    ))


# ---------------------------------------------------------------------------
# quantize_params_once: plane path == per-leaf path, values and grads
# ---------------------------------------------------------------------------


def _sq_loss(quantize):
    def loss(p):
        q, _ = quantize(p, QATConfig())
        return sum(
            jnp.sum(l.astype(jnp.float32) ** 2) for l in jax.tree.leaves(q)
        ), q

    return loss


def _grads_and_quantized(quantize, params):
    """One compile per path: grads of the sq loss + the quantized tree."""
    g, q = jax.jit(
        lambda p: jax.grad(_sq_loss(quantize), has_aux=True)(p)
    )(params)
    return g, q


@pytest.mark.parametrize("tree", ["lenet", "scanned"])
def test_quantize_once_plane_matches_per_leaf(tree, lenet_params,
                                              scanned_params, request):
    params = lenet_params if tree == "lenet" else scanned_params
    assert not quantize_params_once(_stacked_tree(), QATConfig())[1] \
        .quantize_weights
    g_plane, q_plane = _grads_and_quantized(quantize_params_once, params)
    g_leaf, q_leaf = _grads_and_quantized(quantize_params_once_per_leaf,
                                          params)
    for a, b in zip(jax.tree.leaves(q_plane), jax.tree.leaves(q_leaf)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )
    _tree_rel_close(g_plane, g_leaf, tol=1e-5)


def test_quantize_once_values_interpret_backend(lenet_params, monkeypatch):
    """Kernel path (interpret mode) produces the same quantized values."""
    q_ref = quantize_params_once(lenet_params, QATConfig())[0]
    monkeypatch.setenv(dispatch._ENV, "interpret")
    q_int = quantize_params_once(lenet_params, QATConfig())[0]
    for a, b in zip(jax.tree.leaves(q_int), jax.tree.leaves(q_ref)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32)
        )


def test_quantize_once_grads_interpret_backend(lenet_params, monkeypatch):
    """Kernel-path grads vs the jnp chain. Alphas are nudged off the
    |x| == alpha clip boundary (jnp tie-splits the subgradient there, the
    kernels use the closed-form mask — the documented measure-zero
    convention difference). Weight grads match to 1e-5; alpha grads are
    whole-tensor f32 sums with heavy cancellation, so reduction ORDER
    (per-row+segment-sum vs XLA's tree) bounds them at ~1e-3 instead."""
    flat, td = jax.tree_util.tree_flatten_with_path(lenet_params)
    params = jax.tree_util.tree_unflatten(td, [
        leaf * 1.05 if qat.is_clip_key(qat._key_name(p[-1])) else leaf
        for p, leaf in flat
    ])
    g_jnp, _ = _grads_and_quantized(quantize_params_once, params)
    monkeypatch.setenv(dispatch._ENV, "interpret")
    g_int, _ = _grads_and_quantized(quantize_params_once, params)
    for (path, a), (_, b) in zip(
        jax.tree_util.tree_flatten_with_path(g_int)[0],
        jax.tree_util.tree_flatten_with_path(g_jnp)[0],
    ):
        tol = 1e-3 if qat.is_clip_key(qat._key_name(path[-1])) else 1e-5
        _rel_close(a, b, tol)


def test_quantize_once_is_one_launch(lenet_params, scanned_params,
                                     monkeypatch):
    """O(1) in n_tensors: the whole-tree fake-quant enters the fused plane
    dispatcher exactly ONCE at trace time, for 5-leaf LeNet and for the
    scanned stacked-alpha tree alike."""
    calls = []
    orig = dispatch.quant_det_plane
    monkeypatch.setattr(
        dispatch, "quant_det_plane",
        lambda *a, **k: (calls.append(1), orig(*a, **k))[1],
    )
    for params in (lenet_params, scanned_params):
        calls.clear()
        jax.make_jaxpr(lambda p: quantize_params_once(p, QATConfig())[0])(
            params
        )
        assert len(calls) == 1, len(calls)


# ---------------------------------------------------------------------------
# server_optimize on the plane
# ---------------------------------------------------------------------------


def _client_stack(n_clients=4):
    msgs = []
    for i in range(n_clients):
        t = _stacked_tree()
        key = jax.random.fold_in(jax.random.PRNGKey(11), i)
        t = jax.tree.map(
            lambda x: x + 0.05 * jax.random.normal(key, x.shape), t
        )
        msgs.append(t)
    return jax.tree.map(lambda *xs: jnp.stack(xs), *msgs)


def test_server_opt_plane_matches_per_leaf_reference():
    stacked = _client_stack()
    nk = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    cfg = ServerOptConfig(enabled=True, gd_steps=2, lr=0.1, n_grid=6)
    out_p = jax.jit(lambda s, n, k: server_optimize(s, n, k, cfg))(
        stacked, nk, jax.random.PRNGKey(7)
    )
    out_r = jax.jit(lambda s, n, k: server_optimize_reference(s, n, k, cfg))(
        stacked, nk, jax.random.PRNGKey(7)
    )
    _tree_rel_close(out_p, out_r, tol=1e-5)
    # alphas (grid search over identical losses) must agree exactly
    assert float(jnp.max(jnp.abs(
        out_p["s"]["w_qa"] - out_r["s"]["w_qa"]
    ))) == 0.0


def test_server_opt_launch_count_independent_of_n_leaves(monkeypatch):
    """One fused launch per GD step / grid point, NOT per leaf: the trace
    enters the plane quantizers the same number of times for a 1-weight
    tree and a 3-weight (5-segment) tree."""
    counts = {}
    for name in ("fake_quant_plane", "fake_quant_tiles"):
        orig = getattr(dispatch, name)

        def wrap(*a, _orig=orig, _name=name, **k):
            counts[_name] = counts.get(_name, 0) + 1
            return _orig(*a, **k)

        monkeypatch.setattr(dispatch, name, wrap)

    cfg = ServerOptConfig(enabled=True, gd_steps=3, lr=0.1, n_grid=6)
    nk = jnp.ones((4,))

    def trace(stacked):
        counts.clear()
        jax.make_jaxpr(
            lambda s, n, k: server_optimize(s, n, k, cfg)
        )(stacked, nk, jax.random.PRNGKey(0))
        return dict(counts)

    w = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 16))
    one_leaf = {"w": w, "w_qa": jax.vmap(alpha_like)(w)}
    c_small = trace(one_leaf)
    c_big = trace(_client_stack())
    assert c_small == c_big, (c_small, c_big)
    # scan bodies trace once: a handful of entries, never O(n_leaves x steps)
    assert sum(c_big.values()) <= 6, c_big


# ---------------------------------------------------------------------------
# shard-aware plane (2D federated mesh): local specs, no devices needed.
# These are the hypothesis-less twins of the property suite in
# test_properties.py — same invariants on fixed trees, every lane.
# ---------------------------------------------------------------------------

from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.sharding.policy import fed_param_specs  # noqa: E402


class _FakeMesh:
    """Duck-typed mesh: the layout paths only ever read ``mesh.shape``."""

    def __init__(self, **axes):
        self.shape = dict(axes)
        self.axis_names = tuple(axes)


def _shard_leaf(leaf, spec, mesh, index):
    """numpy slice of ``leaf`` at mesh position ``index`` (axis -> coord)."""
    out = np.asarray(leaf)
    for d, ax in enumerate(plane._partition_spec(spec)):
        if ax is None:
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = int(np.prod([mesh.shape[a] for a in axes]))
        k = out.shape[d] // size
        c = 0
        for a in axes:
            c = c * mesh.shape[a] + index[a]
        out = np.take(out, range(c * k, (c + 1) * k), axis=d)
    return jnp.asarray(out)


def _shard_tree(params, specs, mesh, index):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    spec_leaves = treedef.flatten_up_to(specs)
    return jax.tree_util.tree_unflatten(
        treedef,
        [_shard_leaf(l, s, mesh, index)
         for (_, l), s in zip(flat, spec_leaves)],
    )


def test_local_plane_spec_matches_shard_layout(scanned_params):
    """The trace-time local spec IS the spec a shard_map body would build:
    identical to make_plane_spec on an actually-sliced shard tree."""
    mesh = _FakeMesh(fsdp=4)
    specs = fed_param_specs(scanned_params, mesh, axis="fsdp")
    lspec = plane.make_local_plane_spec(scanned_params, specs, mesh)
    shard0 = _shard_tree(scanned_params, specs, mesh, {"fsdp": 0})
    want = plane.make_plane_spec(shard0)
    assert lspec.n_rows == want.n_rows
    assert lspec.seg_sizes == want.seg_sizes
    assert lspec.q_shapes == want.q_shapes
    np.testing.assert_array_equal(np.asarray(lspec.row_seg),
                                  np.asarray(want.row_seg))


def test_local_plane_preserves_alpha_segment_granularity(scanned_params):
    """Sharding never merges or splits alpha segments: the local plane has
    the SAME segment structure as the global one (row counts shrink, the
    stacked per-layer alpha pairing does not), and every sharded leaf's
    segment sizes shrink by exactly its shard factor."""
    mesh = _FakeMesh(fsdp=4)
    specs = fed_param_specs(scanned_params, mesh, axis="fsdp")
    gspec = plane.make_plane_spec(scanned_params)
    lspec = plane.make_local_plane_spec(scanned_params, specs, mesh)
    assert lspec.n_seg == gspec.n_seg
    assert lspec.leaf_segs == gspec.leaf_segs
    assert lspec.q_names == gspec.q_names
    sharded = 0
    for qi in range(len(gspec.q_slots)):
        factor = (int(np.prod(gspec.q_shapes[qi]))
                  // int(np.prod(lspec.q_shapes[qi])))
        sharded += factor > 1
        s0, n = gspec.leaf_seg0[qi], gspec.leaf_segs[qi]
        for si in range(s0, s0 + n):
            assert lspec.seg_sizes[si] * factor == gspec.seg_sizes[si], (
                gspec.q_names[qi], si)
    assert sharded >= 2  # the policy actually sharded something


def test_local_plane_reconstruction_gathers_to_global(scanned_params):
    """Packing each device's local shard tree and unpacking per leaf, then
    concatenating the shards along the sharded dim, reproduces the global
    leaf bitwise — the invariant that makes per-device planes a valid
    decomposition of the global plane."""
    F = 4
    mesh = _FakeMesh(fsdp=F)
    specs = fed_param_specs(scanned_params, mesh, axis="fsdp")
    lspec = plane.make_local_plane_spec(scanned_params, specs, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(scanned_params)
    spec_leaves = treedef.flatten_up_to(specs)
    planes = [
        plane.pack_tiles(
            _shard_tree(scanned_params, specs, mesh, {"fsdp": i}), lspec
        )[0]
        for i in range(F)
    ]
    for qi, slot in enumerate(lspec.q_slots):
        sp = spec_leaves[slot]
        dims = [d for d, ax in enumerate(sp) if ax is not None]
        recon = [np.asarray(plane.leaf_from_tiles(planes[i], lspec, qi))
                 for i in range(F)]
        name = lspec.q_names[qi]
        if dims:
            full = np.concatenate(recon, axis=dims[0])
        else:
            full = recon[0]
            for other in recon[1:]:  # replicated leaves identical everywhere
                np.testing.assert_array_equal(other, full, err_msg=name)
        np.testing.assert_array_equal(full, np.asarray(flat[slot][1]),
                                      err_msg=name)


def test_local_plane_pads_rows_with_zeros():
    """plane_pad_elems counts exactly the layout's zero fill: every
    segment's row block is zero past its real elements, and byte
    accounting (seg_sizes) never charges the padding."""
    mesh = _FakeMesh(fsdp=2)
    tree = _stacked_tree()
    specs = {"d": {"w": P(None, "fsdp"), "w_qa": P(), "b": P()},
             "s": {"w": P(None, None, "fsdp"), "w_qa": P()}}
    lspec = plane.make_local_plane_spec(tree, specs, mesh)
    assert plane.plane_pad_elems(lspec) == (
        lspec.n_rows * plane.LANE - sum(lspec.seg_sizes))
    assert plane.plane_pad_elems(lspec) >= 0
    x2 = np.asarray(plane.pack_tiles(
        _shard_tree(tree, specs, mesh, {"fsdp": 1}), lspec)[0])
    for si in range(lspec.n_seg):
        r0, rows = lspec.seg_row0[si], lspec.seg_rows[si]
        tail = x2[r0:r0 + rows].reshape(-1)[lspec.seg_sizes[si]:]
        assert np.all(tail == 0.0), si


def test_local_plane_spec_rejects_sharded_leading_layer_axis():
    tree = _stacked_tree()
    specs = {"d": {"w": P(), "w_qa": P(), "b": P()},
             "s": {"w": P("fsdp"), "w_qa": P()}}
    with pytest.raises(ValueError, match="leading layer"):
        plane.make_local_plane_spec(tree, specs, _FakeMesh(fsdp=2))


def test_local_plane_spec_rejects_sharded_alphas():
    tree = _stacked_tree()
    specs = {"d": {"w": P(None, "fsdp"), "w_qa": P("fsdp"), "b": P()},
             "s": {"w": P(), "w_qa": P()}}
    with pytest.raises(ValueError, match="replicated"):
        plane.make_local_plane_spec(tree, specs, _FakeMesh(fsdp=2))


def test_local_shape_and_divisibility():
    mesh = _FakeMesh(clients=2, fsdp=4)
    assert plane.local_shape((8, 16), P(None, "fsdp"), mesh) == (8, 4)
    assert plane.local_shape((8, 16), P(("clients", "fsdp"),), mesh) == (1, 16)
    with pytest.raises(ValueError, match="not divisible"):
        plane.local_shape((8, 6), P(None, "fsdp"), mesh)


def test_quantize_det_sharded_needs_mesh_for_plain_specs():
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    tree = {"w": w, "w_qa": alpha_like(w)}
    with pytest.raises(ValueError, match="mesh"):
        plane.quantize_det_sharded(tree, {"w": P(None, "fsdp"), "w_qa": P()})


def test_quantize_det_sharded_replicated_fallback():
    """Fully replicated specs take the plain-plane path — bitwise equal to
    quantize_det, and no shard_map (so a duck-typed mesh suffices)."""
    w = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
    tree = {"w": w, "w_qa": alpha_like(w), "b": jnp.zeros((16,))}
    got = plane.quantize_det_sharded(
        tree, {"w": P(), "w_qa": P(), "b": P()}, mesh=_FakeMesh(fsdp=4))
    want = plane.quantize_det(tree)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
