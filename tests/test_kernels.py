"""Pallas kernel correctness: sweep shapes/dtypes, allclose vs ref.py oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fp8
from repro.core.fp8 import E4M3, E5M2
from repro.kernels import fp8_matmul, fp8_quant, ops, ref

SHAPES = [(8, 128), (16, 256), (256, 512), (300, 200), (1, 128), (129, 384)]
DTYPES = [jnp.float32, jnp.bfloat16]
FMTS = [E4M3, E5M2]


def assert_quant_close(got, want, fmt, max_flip_frac=3e-4):
    """Quantizer outputs must agree except for boundary flips.

    Compiled (pallas/XLA) exp2/log2 differ from the eager oracle by 1 ULP;
    elements landing exactly on a floor/round boundary may then pick the
    *adjacent* grid point. Low-precision inputs (bf16) sit on round ties
    *systematically*, so for them only the one-grid-step bound applies; f32
    inputs hit ties with ~0 probability, so their flip fraction must be tiny.
    """
    g = np.asarray(got, np.float32)
    w = np.asarray(want, np.float32)
    denom = np.maximum(np.abs(w), 1e-30)
    rel = np.abs(g - w) / denom
    if np.asarray(got).dtype == np.float32:
        flips = rel > 1e-5
        assert flips.mean() <= max_flip_frac, f"flip fraction {flips.mean():.2e}"
    one_step = 2.0 ** (-fmt.mant) * 1.01 + 1e-6
    assert rel.max() <= one_step, f"max rel dev {rel.max():.3e} > one grid step"


def _data(shape, dtype, seed=0, scale=1.0):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32) * scale
    return x.astype(dtype)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_quant_det_matches_oracle(shape, dtype, fmt):
    x = _data(shape, dtype)
    alpha = jnp.max(jnp.abs(x.astype(jnp.float32))) * 0.9
    got = fp8_quant.quant_det(x, alpha, fmt=fmt, interpret=True)
    want = ref.quant_det_ref(x, alpha, fmt)
    assert_quant_close(got, want, fmt)


@pytest.mark.parametrize("shape", SHAPES[:4])
@pytest.mark.parametrize("fmt", FMTS)
def test_quant_det_matches_core(shape, fmt):
    """Kernel vs the production core implementation (independent code path)."""
    x = _data(shape, jnp.float32, seed=3)
    alpha = jnp.max(jnp.abs(x))
    got = fp8_quant.quant_det(x, alpha, fmt=fmt, interpret=True)
    want = fp8.quantize_det(x, alpha, fmt)
    assert_quant_close(got, want, fmt)


@pytest.mark.parametrize("shape", SHAPES)
@pytest.mark.parametrize("fmt", FMTS)
def test_quant_rand_matches_oracle(shape, fmt):
    x = _data(shape, jnp.float32, seed=1)
    alpha = jnp.max(jnp.abs(x))
    bits = jax.random.bits(jax.random.PRNGKey(7), shape=shape, dtype=jnp.uint32)
    got = fp8_quant.quant_rand(x, alpha, bits, fmt=fmt, interpret=True)
    want = ref.quant_rand_ref(x, alpha, bits, fmt)
    assert_quant_close(got, want, fmt)


def test_quant_rand_unbiased_kernel():
    x = _data((4, 128), jnp.float32, seed=2, scale=0.3)
    alpha = jnp.max(jnp.abs(x))
    acc = np.zeros(x.shape, np.float64)
    n = 600
    for i in range(n):
        acc += np.asarray(
            ops.quantize_rand_kernel(x, alpha, jax.random.PRNGKey(i))
        )
    bias = np.abs(acc / n - np.asarray(x)).max()
    # stderr of the mean ~ s/sqrt(n); grid step near |x|~0.3 is ~0.02
    assert bias < 5e-3, bias


@pytest.mark.parametrize(
    "m,k,n", [(128, 128, 128), (256, 512, 256), (300, 256, 128), (64, 384, 512)]
)
@pytest.mark.parametrize("dtype", DTYPES)
def test_qat_matmul_matches_oracle(m, k, n, dtype):
    x = _data((m, k), dtype, seed=4, scale=0.5)
    w = _data((k, n), dtype, seed=5, scale=0.1)
    beta = jnp.asarray(1.5, jnp.float32)
    alpha = jnp.max(jnp.abs(w.astype(jnp.float32)))
    got = fp8_matmul.qat_matmul(x, w, beta, alpha, interpret=True)
    want = ref.qat_matmul_ref(x, w, beta, alpha)
    # bf16 inputs can sit exactly on FP8 rounding ties; 1-ULP compile/eager
    # differences then flip single grid choices, moving the dot product by
    # one grid step (~0.04 here). f32 inputs are tie-free w.h.p.
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(want, np.float32),
        rtol=5e-2 if dtype == jnp.bfloat16 else 1e-5,
        atol=8e-2 if dtype == jnp.bfloat16 else 1e-4,
    )


def test_qat_matmul_blocking_invariance():
    """Result must not depend on the BlockSpec tiling."""
    x = _data((256, 384), jnp.float32, seed=6, scale=0.4)
    w = _data((384, 256), jnp.float32, seed=7, scale=0.2)
    beta = jnp.asarray(1.2, jnp.float32)
    alpha = jnp.max(jnp.abs(w))
    a = fp8_matmul.qat_matmul(x, w, beta, alpha, bm=64, bk=128, bn=64, interpret=True)
    b = fp8_matmul.qat_matmul(x, w, beta, alpha, bm=256, bk=384, bn=256, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_ste_wrapper_gradients():
    """Kernel-backed STE must match jnp autodiff of the core implementation:
    grad wrt x is the clip mask; grad wrt alpha is the signed overflow mass
    PLUS the scale term (q - y) * s / alpha from the differentiable
    exponent bias (see kernels/dispatch.py docstring)."""
    x = _data((32, 128), jnp.float32, seed=8)
    alpha = jnp.asarray(0.5 * float(jnp.max(jnp.abs(x))), jnp.float32)

    gk = jax.grad(lambda xx: jnp.sum(ops.quantize_det_ste(xx, alpha)))(x)
    gx_oracle = jax.grad(lambda xx: jnp.sum(fp8.quantize_det(xx, alpha)))(x)
    mask = (jnp.abs(x) <= alpha).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(mask), atol=1e-6)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gx_oracle),
                               atol=1e-6)

    ga = jax.grad(lambda a: jnp.sum(ops.quantize_det_ste(x, a)), argnums=0)(alpha)
    ga_oracle = jax.grad(lambda a: jnp.sum(fp8.quantize_det(x, a)))(alpha)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(ga_oracle),
                               rtol=1e-5, atol=1e-5)
