"""Federated round loop + checkpoint restart: resumed run must continue
from the same server state (fault-tolerance invariant)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.checkpoint.manager import load_checkpoint, save_checkpoint
from repro.core.fedavg import FedConfig
from repro.core.fedsim import FedSim
from repro.core.qat import QATConfig, clip_value_mask, weight_decay_mask
from repro.data import partition_iid, synthetic_classification
from repro.models import small

pytestmark = pytest.mark.slow  # multi-round federated sim, ~11s


def _sim(params):
    xall, yall = synthetic_classification(0, 1200, d=16, n_classes=4)
    cx, cy, nk = partition_iid(xall, yall, k=6, seed=0)
    _, apply = small.REGISTRY["mlp"]
    loss = small.make_loss(apply)
    cfg = FedConfig(n_clients=6, participation=0.5, local_steps=5,
                    batch_size=16, comm_mode="rand", qat=QATConfig())
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    return FedSim(params, loss, apply, opt, cfg, jnp.asarray(cx),
                  jnp.asarray(cy), jnp.asarray(nk))


def test_checkpoint_restart_continues_identically(tmp_path):
    init, _ = small.REGISTRY["mlp"]
    params0 = init(jax.random.PRNGKey(0), d_in=16, n_classes=4)

    # run 1: 4 rounds straight
    sim_a = _sim(params0)
    key = jax.random.PRNGKey(9)
    for r in range(4):
        key, k = jax.random.split(key)
        sim_a.state, _ = sim_a._round(sim_a.state, sim_a.client_data,
                                      sim_a.client_labels, sim_a.nk, k)

    # run 2: 2 rounds, checkpoint, restore into a FRESH sim, 2 more rounds
    sim_b = _sim(params0)
    key = jax.random.PRNGKey(9)
    for r in range(2):
        key, k = jax.random.split(key)
        sim_b.state, _ = sim_b._round(sim_b.state, sim_b.client_data,
                                      sim_b.client_labels, sim_b.nk, k)
    save_checkpoint(str(tmp_path), 2, {"params": sim_b.params},
                    extra={"key": np.asarray(key).tolist()})

    sim_c = _sim(params0)
    restored, manifest = load_checkpoint(str(tmp_path), {"params": sim_c.params})
    sim_c.params = jax.tree.map(jnp.asarray, restored["params"])
    key = jnp.asarray(manifest["extra"]["key"], jnp.uint32)
    for r in range(2):
        key, k = jax.random.split(key)
        sim_c.state, _ = sim_c._round(sim_c.state, sim_c.client_data,
                                      sim_c.client_labels, sim_c.nk, k)

    for a, b in zip(jax.tree.leaves(sim_a.params), jax.tree.leaves(sim_c.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
