import os

# Multi-device lane (tests/test_engine_sharded.py): REPRO_VIRTUAL_DEVICES=8
# forces that many virtual CPU devices. The flag must land in XLA_FLAGS
# before jax initializes — conftest imports before any test module, and
# nothing here imports jax — so the whole pytest process runs on the forced
# topology. Without the env var nothing changes and the sharded tests skip.
_n = os.environ.get("REPRO_VIRTUAL_DEVICES")
if _n and "xla_force_host_platform_device_count" not in os.environ.get(
    "XLA_FLAGS", ""
):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={_n}"
    ).strip()

import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")


@pytest.fixture(scope="session")
def virtual_devices():
    """The multi-device lane's 8 CPU devices; skips (not fails) on a plain
    single-device run so the fast/full lanes stay green without the flag."""
    import jax

    devs = jax.devices()
    if len(devs) < 8:
        pytest.skip(
            "needs >= 8 devices: run with REPRO_VIRTUAL_DEVICES=8 "
            "(the CI multi-device matrix entry does)"
        )
    return devs
