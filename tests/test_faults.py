"""Fault layer (ISSUE 6): bitwise none()==legacy, partial-cohort
renormalization, degenerate cohorts, quorum policies, exact partial byte
accounting, and corruption semantics.

The two load-bearing invariants:

* ``FaultModel.none()`` (or ``faults=None``) leaves the engine on its
  legacy round build — BIT-identical states and metrics for every
  executor, seed-swept.
* The traced ``wire_bytes`` of a fault round equals the static partial
  accounting (``RoundEngine.partial_round_bytes`` and
  ``metrics.partial_round_bytes``) at the realized transmit count:
  P downlink copies, transmitted-uplink payloads only.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import metrics as metrics_lib
from repro.core.engine import (
    ChunkedExecutor,
    FedConfig,
    RoundEngine,
    VmapExecutor,
    make_local_update,
)
from repro.core.faults import FaultDraw, FaultModel, quorum_count
from repro.core.qat import (
    DISABLED,
    QATConfig,
    clip_value_mask,
    weight_decay_mask,
)
from repro.core.server_opt import weighted_mean
from repro.data import client_latencies, partition_iid, \
    synthetic_classification
from repro.models import small


def _mlp_setup(k=6, n=600, d=16, n_classes=4):
    xall, yall = synthetic_classification(0, n + 300, d=d, n_classes=n_classes)
    cx, cy, nk = partition_iid(xall[:n], yall[:n], k=k, seed=0)
    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=d, n_classes=n_classes)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    return params, loss, apply, opt, (jnp.asarray(cx), jnp.asarray(cy),
                                      jnp.asarray(nk))


def _assert_trees_equal(a, b, msg=""):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(pa), np.asarray(pb),
                                      err_msg=msg)


def _any_leaf_differs(a, b):
    return any(
        not np.array_equal(np.asarray(pa), np.asarray(pb))
        for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


_BASE = dict(n_clients=6, participation=0.5, local_steps=2, batch_size=8,
             comm_mode="rand", qat=QATConfig())


# ---------------------------------------------------------------------------
# Bitwise invariant: none() == legacy, every executor, seed-swept
# ---------------------------------------------------------------------------


def test_faultmodel_none_bitwise_legacy_seed_swept():
    """faults=FaultModel.none() (even with a quorum configured) must leave
    the engine on the LEGACY trace: bit-identical params and metrics for
    the vmap and chunked executors across seeds, and no fault metrics."""
    params, loss, apply, opt, data = _mlp_setup()
    legacy_cfg = FedConfig(**_BASE)
    none_cfg = FedConfig(**_BASE, faults=FaultModel.none(), min_quorum=0.5)
    for executor in (VmapExecutor(), ChunkedExecutor(2)):
        legacy = RoundEngine(loss, opt, legacy_cfg, executor=executor)
        faulty = RoundEngine(loss, opt, none_cfg, executor=executor)
        assert faulty.faults is None, "none() must statically elide"
        f_legacy = jax.jit(legacy.round_fn)
        f_none = jax.jit(faulty.round_fn)
        for seed in range(4):
            key = jax.random.PRNGKey(seed)
            s0, m0 = f_legacy(legacy.init(params), *data, key)
            s1, m1 = f_none(faulty.init(params), *data, key)
            _assert_trees_equal(s0.params, s1.params,
                                f"seed {seed}: none() diverged from legacy")
            assert set(m0) == set(m1) == {"local_loss", "wire_bytes"}
            np.testing.assert_array_equal(np.asarray(m0["local_loss"]),
                                          np.asarray(m1["local_loss"]))
            assert int(m0["wire_bytes"]) == int(m1["wire_bytes"])


def test_straggler_inf_deadline_active_but_lossless():
    """A straggler distribution with an infinite deadline drops nobody —
    params must equal the legacy round exactly (every client survives, so
    the masked aggregation degenerates to the legacy one) — but the fault
    path IS active: it reports the cohort's slowest latency as round_time
    (the sync time-to-accuracy clock)."""
    params, loss, apply, opt, data = _mlp_setup()
    fm = FaultModel(straggler="lognormal", straggler_scale=2.0,
                    straggler_param=0.5, seed=3)
    assert not fm.is_none
    legacy = RoundEngine(loss, opt, FedConfig(**_BASE))
    eng = RoundEngine(loss, opt, FedConfig(**_BASE, faults=fm))
    key = jax.random.PRNGKey(11)
    s0, m0 = jax.jit(legacy.round_fn)(legacy.init(params), *data, key)
    s1, m1 = jax.jit(eng.round_fn)(eng.init(params), *data, key)
    _assert_trees_equal(s0.params, s1.params)
    P = eng.cohort
    assert int(m1["n_alive"]) == int(m1["n_transmitted"]) == P
    assert int(m1["round_ok"]) == 1
    assert int(m1["wire_bytes"]) == int(m0["wire_bytes"])
    lat = np.asarray(fm.latencies(_BASE["n_clients"]))
    t = float(m1["round_time"])
    # the cohort max is one of the pool latencies, and >= the pool min
    assert any(math.isclose(t, float(v), rel_tol=1e-6) for v in lat)


# ---------------------------------------------------------------------------
# Degenerate cohorts
# ---------------------------------------------------------------------------


def test_all_dropped_round_skipped():
    """dropout=1.0: nobody transmits. The round must be discarded (params
    AND stateful-aggregator momentum untouched, finite), charge 0 uplink
    bytes, and report itself dead."""
    params, loss, apply, opt, data = _mlp_setup()
    cfg = FedConfig(**_BASE, faults=FaultModel(dropout=1.0),
                    aggregator="fedavgm", server_lr=1.0, server_momentum=0.9)
    eng = RoundEngine(loss, opt, cfg)
    state0 = eng.init(params)
    state1, m = jax.jit(eng.round_fn)(state0, *data, jax.random.PRNGKey(0))
    assert int(m["n_alive"]) == int(m["n_transmitted"]) == 0
    assert int(m["quorum_met"]) == 0 and int(m["round_ok"]) == 0
    _assert_trees_equal(state0.params, state1.params,
                        "skipped round must not move params")
    _assert_trees_equal(state0.opt, state1.opt,
                        "skipped round must not move aggregator state")
    for leaf in jax.tree.leaves(state1.params):
        assert np.all(np.isfinite(np.asarray(leaf)))
    want = eng.partial_round_bytes(0, params)
    assert int(m["wire_bytes"]) == want
    assert metrics_lib.partial_round_bytes(params, cfg, 0) == want


def test_quorum_boundary_skip_vs_degrade():
    """Find a seed with exactly 2/3 survivors; then min_quorum=2 commits
    the round, min_quorum=3 discards it, and 'degrade' proceeds even
    below quorum (while still reporting quorum_met=0)."""
    params, loss, apply, opt, data = _mlp_setup()

    def build(min_quorum, policy="skip"):
        cfg = FedConfig(**_BASE, faults=FaultModel(dropout=0.5),
                        min_quorum=min_quorum, quorum_policy=policy)
        e = RoundEngine(loss, opt, cfg)
        return e, jax.jit(e.round_fn)

    eng2, f2 = build(2)
    key = None
    for seed in range(64):
        k = jax.random.PRNGKey(seed)
        _, m = f2(eng2.init(params), *data, k)
        if int(m["n_alive"]) == 2:
            key = k
            break
    assert key is not None, "no seed with exactly 2 survivors in 64 draws"

    s2, m2 = f2(eng2.init(params), *data, key)
    assert int(m2["quorum_met"]) == 1 and int(m2["round_ok"]) == 1
    assert _any_leaf_differs(params, s2.params), \
        "at-quorum round must commit"

    eng3, f3 = build(3)
    s3, m3 = f3(eng3.init(params), *data, key)
    assert int(m3["quorum_met"]) == 0 and int(m3["round_ok"]) == 0
    _assert_trees_equal(params, s3.params, "below-quorum round must skip")

    engd, fd = build(3, policy="degrade")
    sd, md = fd(engd.init(params), *data, key)
    assert int(md["quorum_met"]) == 0 and int(md["round_ok"]) == 1
    _assert_trees_equal(s2.params, sd.params,
                        "degrade must aggregate the same survivors")


def test_partial_renormalization_exact():
    """Independent reconstruction of the partial aggregate: with the FP32
    wire and the mean aggregator, a fault round's params must equal the
    survivors-only nk-weighted mean of the clients' locally-trained
    params — survivor weights renormalized by the surviving nk mass.
    Seeds are swept so single-survivor and multi-survivor (and skipped
    all-dead) rounds are all exercised."""
    params, loss, apply, opt, data = _mlp_setup()
    cx, cy, nk = data
    fm = FaultModel(dropout=0.5)
    cfg = FedConfig(n_clients=6, participation=0.5, local_steps=2,
                    batch_size=8, comm_mode="none", qat=DISABLED,
                    faults=fm, quorum_policy="degrade")
    eng = RoundEngine(loss, opt, cfg)
    round_fn = jax.jit(eng.round_fn)
    local_update = make_local_update(loss, opt, cfg)
    lat_table = fm.latencies(cfg.n_clients)
    P = eng.cohort

    @jax.jit
    def reconstruct(key):
        k_sel, k_down, k_up, k_loc, k_srv = jax.random.split(key, 5)
        idx = eng.sampler(nk, k_sel)
        loc_keys = jax.random.split(k_loc, P)
        trained, _ = jax.vmap(local_update, in_axes=(None, 0, 0, 0))(
            params, cx[idx], cy[idx], loc_keys
        )
        fd = fm.draw(key, idx, lat_table)
        nk_eff = nk[idx] * fd.accepted.astype(jnp.float32)
        # replace rejected rows by the broadcast model, exactly like the
        # engine, then take the renormalized weighted mean
        masked = jax.tree.map(
            lambda m, p: jnp.where(
                fd.accepted.reshape((P,) + (1,) * (m.ndim - 1)),
                m, p[None],
            ),
            trained, params,
        )
        return weighted_mean(masked, nk_eff), fd.accepted

    n_single = n_multi = 0
    for seed in range(8):
        key = jax.random.PRNGKey(seed)
        state, m = round_fn(eng.init(params), *data, key)
        expected, accepted = reconstruct(key)
        n_alive = int(np.sum(np.asarray(accepted)))
        assert n_alive == int(m["n_alive"])
        if n_alive == 0:
            _assert_trees_equal(params, state.params)
            continue
        n_single += n_alive == 1
        n_multi += n_alive > 1
        for got, want in zip(jax.tree.leaves(state.params),
                             jax.tree.leaves(expected)):
            np.testing.assert_allclose(
                np.asarray(got), np.asarray(want), rtol=2e-6, atol=1e-7,
                err_msg=f"seed {seed} ({n_alive} survivors): partial "
                        "aggregate != renormalized survivor mean")
    assert n_single >= 1, "sweep never hit a single-survivor round"
    assert n_multi >= 1, "sweep never hit a multi-survivor round"


def test_single_survivor_chunked_parity():
    """A fault round is still executor-invariant: vmap and chunk=1 (the
    width-2 padding pin from the chunked executor) must agree bitwise
    under active dropout, including on a single-survivor realization."""
    params, loss, apply, opt, data = _mlp_setup()
    cfg = FedConfig(**_BASE, faults=FaultModel(dropout=0.5),
                    quorum_policy="degrade")
    full = RoundEngine(loss, opt, cfg, executor=VmapExecutor())
    f_full = jax.jit(full.round_fn)
    key = None
    for seed in range(64):
        k = jax.random.PRNGKey(seed)
        _, m = f_full(full.init(params), *data, k)
        if int(m["n_alive"]) == 1:
            key = k
            break
    assert key is not None, "no single-survivor seed in 64 draws"
    s_full, m_full = f_full(full.init(params), *data, key)
    chunked = RoundEngine(loss, opt, cfg, executor=ChunkedExecutor(1))
    s_chunk, m_chunk = jax.jit(chunked.round_fn)(
        chunked.init(params), *data, key
    )
    _assert_trees_equal(s_full.params, s_chunk.params,
                        "faulty round: chunked diverged from vmap")
    for name in ("n_alive", "n_transmitted", "wire_bytes", "round_ok"):
        assert int(m_full[name]) == int(m_chunk[name]), name


# ---------------------------------------------------------------------------
# Byte accounting: traced == static, per realized transmit count
# ---------------------------------------------------------------------------


def test_partial_bytes_traced_equals_static():
    """Asymmetric wire (delta uplink) + dropout: the traced wire_bytes
    must equal both static partial accountings at the realized transmit
    count — catching any up/down leg swap or drift."""
    params, loss, apply, opt, data = _mlp_setup()
    cfg = FedConfig(**_BASE, up_codec="delta:e4m3",
                    faults=FaultModel(dropout=0.4))
    eng = RoundEngine(loss, opt, cfg)
    round_fn = jax.jit(eng.round_fn)
    seen = set()
    for seed in range(6):
        _, m = round_fn(eng.init(params), *data, jax.random.PRNGKey(seed))
        n_tx = int(m["n_transmitted"])
        seen.add(n_tx)
        want = eng.partial_round_bytes(n_tx, params)
        assert int(m["wire_bytes"]) == want, (seed, n_tx)
        assert metrics_lib.partial_round_bytes(params, cfg, n_tx) == want
    assert len(seen) > 1, "dropout sweep produced only one transmit count"
    with pytest.raises(ValueError):
        eng.partial_round_bytes(eng.cohort + 1, params)


# ---------------------------------------------------------------------------
# Corruption
# ---------------------------------------------------------------------------


def test_corrupt_detected_charges_uplink_but_excluded():
    """corrupt=1.0 + checksum: every client transmits (full uplink bytes
    charged) yet none is accepted — the round is discarded."""
    params, loss, apply, opt, data = _mlp_setup()
    cfg = FedConfig(**_BASE, faults=FaultModel(corrupt=1.0))
    eng = RoundEngine(loss, opt, cfg)
    state, m = jax.jit(eng.round_fn)(eng.init(params), *data,
                                     jax.random.PRNGKey(5))
    P = eng.cohort
    assert int(m["n_transmitted"]) == P and int(m["n_alive"]) == 0
    assert int(m["round_ok"]) == 0
    assert int(m["wire_bytes"]) == eng.partial_round_bytes(P, params)
    _assert_trees_equal(params, state.params)


def test_corrupt_undetected_flips_propagate():
    """Without the checksum the bit flips survive into aggregation: the
    result must differ from the fault-free round."""
    params, loss, apply, opt, data = _mlp_setup()
    legacy = RoundEngine(loss, opt, FedConfig(**_BASE))
    cfg = FedConfig(**_BASE, faults=FaultModel(
        corrupt=1.0, corrupt_detect=False, corrupt_frac=0.05))
    eng = RoundEngine(loss, opt, cfg)
    key = jax.random.PRNGKey(5)
    s0, _ = jax.jit(legacy.round_fn)(legacy.init(params), *data, key)
    s1, m = jax.jit(eng.round_fn)(eng.init(params), *data, key)
    assert int(m["n_alive"]) == eng.cohort  # undetected => all accepted
    assert _any_leaf_differs(s0.params, s1.params), \
        "undetected corruption left the aggregate untouched"


def test_corrupt_tree_unit():
    """corrupt_tree flips bits only in corrupted clients' f32 rows and
    passes non-f32 leaves through untouched."""
    k = jax.random.PRNGKey(0)
    stacked = {
        "w": jax.random.normal(k, (3, 16, 8)),
        "b": jax.random.normal(k, (3, 8)),
        "i": jnp.arange(6, dtype=jnp.int32).reshape(3, 2),
    }
    fm = FaultModel(corrupt=1.0, corrupt_detect=False, corrupt_frac=0.5)
    corrupted = jnp.asarray([True, False, True])
    out = fm.corrupt_tree(stacked, corrupted, jax.random.PRNGKey(9))
    np.testing.assert_array_equal(np.asarray(out["i"]),
                                  np.asarray(stacked["i"]))
    for name in ("w", "b"):
        got, src = np.asarray(out[name]), np.asarray(stacked[name])
        np.testing.assert_array_equal(got[1], src[1],
                                      err_msg="clean row was damaged")
        assert not np.array_equal(got[0], src[0]), f"{name}[0] not flipped"
        assert not np.array_equal(got[2], src[2]), f"{name}[2] not flipped"


# ---------------------------------------------------------------------------
# round_time / latency tables / quorum_count / config validation
# ---------------------------------------------------------------------------


def test_round_time_semantics():
    lat = jnp.asarray([1.0, 5.0, 3.0])
    ok = jnp.ones(3, bool)
    fm_inf = FaultModel(straggler="lognormal")
    d = FaultDraw(ok, ok, jnp.zeros(3, bool), lat)
    assert float(fm_inf.round_time(d)) == 5.0
    fm = FaultModel(straggler="lognormal", deadline=4.0)
    # all delivered under the deadline: the server closes at the last one
    d_in = FaultDraw(ok, ok, jnp.zeros(3, bool),
                     jnp.asarray([1.0, 2.0, 3.0]))
    assert float(fm.round_time(d_in)) == 3.0
    # anyone missing: the server must wait out the full deadline
    d_out = FaultDraw(jnp.asarray([True, False, True]), ok,
                      jnp.zeros(3, bool), lat)
    assert float(fm.round_time(d_out)) == 4.0


def test_latency_tables_deterministic():
    a = client_latencies(16, dist="pareto", scale=2.0, param=1.1, seed=7)
    b = client_latencies(16, dist="pareto", scale=2.0, param=1.1, seed=7)
    np.testing.assert_array_equal(a, b)
    assert a.shape == (16,) and np.all(a >= 2.0)
    c = client_latencies(16, dist="pareto", scale=2.0, param=1.1, seed=8)
    assert not np.array_equal(a, c)
    np.testing.assert_array_equal(
        client_latencies(4, dist="none", scale=3.0), np.full(4, 3.0))
    with pytest.raises(ValueError):
        client_latencies(4, dist="weibull")


def test_quorum_count():
    assert quorum_count(0.0, 6) == 1     # 0 means "any survivor"
    assert quorum_count(0, 6) == 1
    assert quorum_count(0.5, 6) == 3
    assert quorum_count(0.34, 3) == 2    # fractional quorum rounds UP
    assert quorum_count(1.0, 6) == 6
    assert quorum_count(2, 6) == 2
    assert quorum_count(10, 6) == 6      # clamped to the cohort


def test_faultmodel_validation():
    with pytest.raises(ValueError, match="dropout"):
        FaultModel(dropout=1.5)
    with pytest.raises(ValueError, match="corrupt"):
        FaultModel(corrupt=-0.1)
    with pytest.raises(ValueError, match="straggler"):
        FaultModel(straggler="weibull")
    with pytest.raises(ValueError, match="deadline"):
        FaultModel(deadline=0.0)


def test_fedconfig_validation():
    good = dict(n_clients=6, participation=0.5, local_steps=2, batch_size=8)
    FedConfig(**good)  # sanity: the base is valid
    bad = [
        dict(n_clients=0),
        dict(participation=0.0),
        dict(participation=1.5),
        dict(local_steps=0),
        dict(batch_size=0),
        dict(chunk=0),
        dict(sampler="bogus"),
        dict(aggregator="bogus"),
        dict(quorum_policy="bogus"),
        dict(min_quorum=1.5),
        dict(min_quorum=-1),
        dict(min_quorum=7),     # int above the cohort (=3 here)
        dict(faults=42),
    ]
    for kw in bad:
        with pytest.raises((ValueError, TypeError)):
            FedConfig(**{**good, **kw})
    # a mesh without the client axis must fail eagerly, not deep in jax
    from jax.sharding import Mesh

    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    with pytest.raises(ValueError, match="client_axis|clients"):
        FedConfig(**good, mesh=mesh)
