"""FedSim measurement correctness (ISSUE 2 bugfixes).

* ``FedSim.evaluate`` must weight ragged batches by size — an unweighted
  mean of per-batch accuracies over-weights a smaller final batch.
* The bytes ``FedSim.run`` charges must be the bytes the traced round
  actually moved: ``metrics.round_bytes`` (static estimate) and fedavg's
  ``wire_bytes`` (read off the traced payload) must agree for quantized
  (rand/det) and FP32 (``comm_mode='none'``) configs.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import metrics
from repro.core.fedavg import FedConfig, make_round
from repro.core.fedsim import FedSim
from repro.core.qat import (
    DISABLED,
    QATConfig,
    clip_value_mask,
    weight_decay_mask,
)
from repro.models import small


def _sim(cfg, d=8, n_classes=4):
    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=d, n_classes=n_classes)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    k = cfg.n_clients
    cx = jax.random.normal(jax.random.PRNGKey(1), (k, 16, d))
    cy = jax.random.randint(jax.random.PRNGKey(2), (k, 16), 0, n_classes)
    nk = jnp.full((k,), 16.0)
    return FedSim(params, loss, apply, opt, cfg, cx, cy, nk), apply, params


def test_evaluate_exact_on_ragged_batches():
    """70 examples at batch 32 -> 32/32/6. Labels are built so the head
    batches score 0 and the 6-example tail scores 1: the unweighted
    per-batch mean reports 1/3, the true accuracy is 6/70."""
    cfg = FedConfig(n_clients=2, participation=1.0, local_steps=1,
                    batch_size=4, comm_mode="none", qat=DISABLED)
    sim, apply, params = _sim(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (70, 8))
    pred = jnp.argmax(apply(params, x, cfg.qat), -1)
    y = jnp.concatenate([(pred[:64] + 1) % 4, pred[64:]])  # head wrong, tail right
    got = sim.evaluate(x, y, batch=32)
    assert abs(got - 6.0 / 70.0) < 1e-6, got
    # the bug this regresses: naive per-batch averaging would say 1/3
    assert abs(got - 1.0 / 3.0) > 0.2


@pytest.mark.parametrize("comm_mode,qat_cfg", [
    ("rand", QATConfig()),
    ("det", QATConfig()),
    ("none", DISABLED),
])
def test_static_and_traced_round_bytes_agree(comm_mode, qat_cfg):
    cfg = FedConfig(n_clients=2, participation=1.0, local_steps=1,
                    batch_size=8, comm_mode=comm_mode, qat=qat_cfg)
    sim, _, params = _sim(cfg)
    _, m = sim._round(sim.params, sim.client_data, sim.client_labels,
                      sim.nk, jax.random.PRNGKey(0))
    static = metrics.round_bytes(params, cfg.clients_per_round,
                                 quantized=comm_mode != "none")
    assert static == sim.bytes_per_round
    assert int(m["wire_bytes"]) == static, (int(m["wire_bytes"]), static)
    # and FedSim.run must charge exactly that per round (same jitted round,
    # so this costs no extra compile)
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 8))
    y = jax.random.randint(jax.random.PRNGKey(5), (24,), 0, 4)
    hist = sim.run(2, jax.random.PRNGKey(6), eval_data=(x, y), eval_every=1)
    assert hist.cumulative_bytes == [static, 2 * static]
