"""FedSim measurement correctness (ISSUE 2 bugfixes, ISSUE 3 links).

* ``FedSim.evaluate`` must weight ragged batches by size — an unweighted
  mean of per-batch accuracies over-weights a smaller final batch — and
  must compile ONCE per dataset (the tail batch is padded + masked, not
  retraced at its own shape).
* The bytes ``FedSim.run`` charges must be the bytes the traced round
  actually moved: ``metrics.round_bytes`` (static estimate) and the
  engine's ``wire_bytes`` (read off the traced payload) must agree for
  every link variant — symmetric rand/det/none AND asymmetric
  per-direction links (FP32 down / FP8 up and vice versa, hybrid
  E4M3/E5M2 formats).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import metrics
from repro.core.engine import FedConfig
from repro.core.fedsim import FedSim
from repro.core.fp8 import E4M3, E5M2
from repro.core.qat import (
    DISABLED,
    QATConfig,
    clip_value_mask,
    weight_decay_mask,
)
from repro.models import small


def _sim(cfg, d=8, n_classes=4):
    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=d, n_classes=n_classes)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    k = cfg.n_clients
    cx = jax.random.normal(jax.random.PRNGKey(1), (k, 16, d))
    cy = jax.random.randint(jax.random.PRNGKey(2), (k, 16), 0, n_classes)
    nk = jnp.full((k,), 16.0)
    return FedSim(params, loss, apply, opt, cfg, cx, cy, nk), apply, params


def test_evaluate_exact_on_ragged_batches():
    """70 examples at batch 32 -> 32/32/6. Labels are built so the head
    batches score 0 and the 6-example tail scores 1: the unweighted
    per-batch mean reports 1/3, the true accuracy is 6/70."""
    cfg = FedConfig(n_clients=2, participation=1.0, local_steps=1,
                    batch_size=4, comm_mode="none", qat=DISABLED)
    sim, apply, params = _sim(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (70, 8))
    pred = jnp.argmax(apply(params, x, cfg.qat), -1)
    y = jnp.concatenate([(pred[:64] + 1) % 4, pred[64:]])  # head wrong, tail right
    got = sim.evaluate(x, y, batch=32)
    assert abs(got - 6.0 / 70.0) < 1e-6, got
    # the bug this regresses: naive per-batch averaging would say 1/3
    assert abs(got - 1.0 / 3.0) > 0.2


def test_evaluate_compiles_once_per_dataset():
    """The ragged tail batch must NOT trigger a second trace: it is padded
    to the head batch shape and masked. One dataset -> one compile."""
    cfg = FedConfig(n_clients=2, participation=1.0, local_steps=1,
                    batch_size=4, comm_mode="none", qat=DISABLED)
    sim, apply, params = _sim(cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (70, 8))
    y = jnp.zeros((70,), jnp.int32)
    traces = []
    orig = sim._eval
    inner = orig.__wrapped__

    def counting(params, xb, yb, n_valid):
        traces.append(tuple(xb.shape))
        return inner(params, xb, yb, n_valid)

    sim._eval = jax.jit(counting)
    sim.evaluate(x, y, batch=32)   # 32/32/6 -> padded tail, one shape
    assert set(traces) == {(32, 8)}, traces
    assert len(traces) == 1, f"re-traced on the ragged tail: {traces}"
    sim._eval = orig


LINK_VARIANTS = [
    # (id, cfg kwargs, down quantized?, up quantized?)
    ("rand", dict(comm_mode="rand", qat=QATConfig()), True, True),
    ("det", dict(comm_mode="det", qat=QATConfig()), True, True),
    ("none", dict(comm_mode="none", qat=DISABLED), False, False),
    ("fp32_down_fp8_up",
     dict(comm_mode="rand", qat=QATConfig(), down_mode="none"), False, True),
    ("fp8_down_fp32_up",
     dict(comm_mode="rand", qat=QATConfig(), up_mode="none"), True, False),
    ("hybrid_e4m3_e5m2",
     dict(comm_mode="rand", qat=QATConfig(), down_fmt=E4M3, up_fmt=E5M2),
     True, True),
]


@pytest.mark.parametrize(
    "kwargs,down_q,up_q",
    [v[1:] for v in LINK_VARIANTS],
    ids=[v[0] for v in LINK_VARIANTS],
)
def test_static_and_traced_round_bytes_agree(kwargs, down_q, up_q):
    cfg = FedConfig(n_clients=2, participation=1.0, local_steps=1,
                    batch_size=8, **kwargs)
    sim, _, params = _sim(cfg)
    _, m = sim._round(sim.state, sim.client_data, sim.client_labels,
                      sim.nk, jax.random.PRNGKey(0))
    static = metrics.round_bytes(params, cfg.clients_per_round,
                                 quantized=down_q, up_quantized=up_q)
    assert static == sim.bytes_per_round
    assert static == metrics.round_bytes_for(params, cfg)
    assert int(m["wire_bytes"]) == static, (int(m["wire_bytes"]), static)
    # and FedSim.run must charge exactly that per round (same jitted round,
    # so this costs no extra compile)
    x = jax.random.normal(jax.random.PRNGKey(4), (24, 8))
    y = jax.random.randint(jax.random.PRNGKey(5), (24,), 0, 4)
    hist = sim.run(2, jax.random.PRNGKey(6), eval_data=(x, y), eval_every=1)
    assert hist.cumulative_bytes == [static, 2 * static]


def test_asymmetric_links_differ_from_symmetric():
    """FP32-down/FP8-up must charge MORE than symmetric FP8 and LESS than
    symmetric FP32 — the per-direction accounting is real, not collapsed
    onto one flag."""
    init, _ = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=8, n_classes=4)
    both = metrics.round_bytes(params, 2, quantized=True)
    neither = metrics.round_bytes(params, 2, quantized=False)
    mixed = metrics.round_bytes(params, 2, quantized=False, up_quantized=True)
    assert both < mixed < neither
    assert mixed == (both + neither) // 2
