"""Data substrate: synthetic generators, partitioners, LM batcher."""
import numpy as np

from repro.data import (
    partition_by_speaker,
    partition_dirichlet,
    partition_iid,
    synthetic_classification,
    synthetic_images,
    synthetic_lm_tokens,
    synthetic_sequences,
)
from repro.data.pipeline import LMBatcher, silo_stream


def test_generators_shapes_and_determinism():
    x1, y1 = synthetic_classification(7, 100, d=16, n_classes=5)
    x2, y2 = synthetic_classification(7, 100, d=16, n_classes=5)
    np.testing.assert_array_equal(x1, x2)
    assert x1.shape == (100, 16) and y1.max() < 5

    xi, yi = synthetic_images(1, 20, hw=16, channels=3, n_classes=4)
    assert xi.shape == (20, 16, 16, 3)
    xs, ys = synthetic_sequences(2, 20, t=8, feats=12, n_classes=6)
    assert xs.shape == (20, 8, 12)

    t = synthetic_lm_tokens(3, 1000, vocab=128)
    assert t.shape == (1000,) and t.max() < 128
    # markov structure => non-uniform bigram distribution
    big = {}
    for a, b in zip(t[:-1], t[1:]):
        big[(a, b)] = big.get((a, b), 0) + 1
    top = max(big.values())
    assert top > 3, "token stream has no learnable structure"


def test_partitioners():
    x, y = synthetic_classification(0, 2000, d=8, n_classes=10)
    cx, cy, nk = partition_iid(x, y, k=10, seed=0)
    assert cx.shape[0] == 10 and nk.shape == (10,)
    cx2, cy2, nk2 = partition_dirichlet(x, y, k=10, concentration=0.1, seed=0)
    assert cx2.shape[0] == 10
    spk = np.repeat(np.arange(8), 250)
    cx3, cy3, nk3 = partition_by_speaker(x, y, spk, seed=0)
    assert cx3.shape[0] == 8
    assert np.all(nk3 == 250)


def test_lm_batcher_deterministic_and_resumable():
    stream = synthetic_lm_tokens(0, 10_000, vocab=64)
    b = LMBatcher(stream, batch=4, seq_len=16)
    one = b(3)
    two = b(3)
    np.testing.assert_array_equal(one["tokens"], two["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(one["tokens"][:, 1:], one["labels"][:, :-1])
    # distinct steps -> distinct windows (until wraparound)
    assert not np.array_equal(b(0)["tokens"], b(1)["tokens"])


def test_silo_streams_distinct():
    a = silo_stream(64, 1000, silo=0)
    b = silo_stream(64, 1000, silo=1)
    assert not np.array_equal(a, b)
