"""Validate the loop-aware HLO cost model against unrolled ground truth."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_cost import analyze


def _flops(fn, *specs):
    compiled = jax.jit(fn).lower(*specs).compile()
    return analyze(compiled.as_text())["flops"]


def test_scan_trip_count_multiplies():
    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    w = jax.ShapeDtypeStruct((512, 512), jnp.float32)

    def scanned(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    def unrolled(x, w):
        for _ in range(10):
            x = x @ w
        return x

    fs = _flops(scanned, x, w)
    fu = _flops(unrolled, x, w)
    expected = 10 * 2 * 512**3
    assert fu == pytest.approx(expected, rel=0.01)
    assert fs == pytest.approx(fu, rel=0.05), (fs, fu)


def test_nested_scan():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def nested(x, w):
        def inner(c, _):
            return c @ w, None

        def outer(c, _):
            c, _ = jax.lax.scan(inner, c, None, length=4)
            return c, None

        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    f = _flops(nested, x, w)
    expected = 12 * 2 * 256**3
    assert f == pytest.approx(expected, rel=0.05), f


def test_dot_general_batched():
    a = jax.ShapeDtypeStruct((8, 128, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((8, 64, 32), jnp.float32)
    f = _flops(lambda a, b: jnp.einsum("bij,bjk->bik", a, b), a, b)
    assert f == pytest.approx(2 * 8 * 128 * 64 * 32, rel=0.01), f


def test_bytes_reasonable():
    x = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    out = analyze(jax.jit(lambda x: x + 1.0).lower(x).compile().as_text())
    nbytes = 1024 * 1024 * 4
    # read + write = 2 buffers; allow fusion bookkeeping slack
    assert nbytes <= out["bytes"] <= 4 * nbytes, out["bytes"]
