"""Per-assigned-architecture smoke tests on REDUCED configs (CPU).

For each of the 10 archs: instantiate the reduced same-family config, run
one QAT train step (forward + grad + SGD update) and one decode step,
asserting output shapes and finiteness. Full configs are exercised only by
the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.qat import QATConfig, weight_decay_mask
from repro.models.registry import get_model
from repro import optim
from repro.optim.base import apply_updates

pytestmark = pytest.mark.slow  # full-arch sweep, ~160s of the suite

QCFG = QATConfig()
B, S = 2, 32


def _batch(cfg, key):
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["features"] = jax.random.normal(
            key, (B, cfg.encoder_len, cfg.d_model), jnp.float32
        )
    if cfg.n_patches:
        batch["patches"] = jax.random.normal(
            key, (B, cfg.n_patches, cfg.d_model), jnp.float32
        )
    return batch


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step(arch):
    cfg = configs.reduced(configs.get(arch))
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params = model.init(key)
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss, grads = jax.value_and_grad(model.train_loss)(params, batch, QCFG)
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss"
    gn = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                      for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0, f"{arch}: bad grads"

    opt = optim.sgd(0.01, weight_decay=1e-4, wd_mask=weight_decay_mask(params))
    state = opt.init(params)
    upd, _ = opt.update(grads, state, params, jnp.zeros((), jnp.int32))
    new_params = apply_updates(params, upd)
    # params actually changed and stayed finite
    changed = any(
        float(jnp.max(jnp.abs(a - b))) > 0
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(new_params))
    )
    assert changed, f"{arch}: update was a no-op"
    loss2 = model.train_loss(new_params, batch, QCFG)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_decode_step(arch):
    cfg = configs.reduced(configs.get(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, S)
    token = jnp.zeros((B,), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, token, jnp.int32(0), QCFG)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    # second step with updated cache
    logits, _ = model.decode_step(params, cache2, token, jnp.int32(1), QCFG)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))


@pytest.mark.parametrize("arch", ["tinyllama_1_1b", "mamba2_1_3b",
                                  "recurrentgemma_2b", "whisper_medium"])
def test_prefill(arch):
    cfg = configs.reduced(configs.get(arch))
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    logits, cache = model.prefill(params, batch, QCFG)
    assert logits.shape == (B, cfg.vocab)
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
