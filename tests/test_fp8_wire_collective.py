"""FP8-wire federated collective: correctness + actual u8 payload on the wire."""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compression
from repro.core.qat import alpha_like


def _params():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    return {"w": w, "w_qa": alpha_like(w), "b": jnp.ones((64,))}


def test_fp8_wire_mean_unbiased_single_device():
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()

    fn = jax.jit(shard_map(
        lambda p, k: compression.fp8_wire_allreduce_mean(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    ))
    acc = np.zeros(params["w"].shape, np.float64)
    n = 150
    for i in range(n):
        acc += np.asarray(fn(params, jax.random.PRNGKey(i))["w"])
    bias = np.abs(acc / n - np.asarray(params["w"])).max()
    assert bias < 2.5e-2, bias
    out = fn(params, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(params["b"]))


def test_fp8_wire_collective_moves_uint8():
    """The lowered collective must carry u8, not f32 — the 4x is real."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()
    fn = shard_map(
        lambda p, k: compression.fp8_wire_allreduce_mean(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    )
    txt = jax.jit(fn).lower(params, jax.random.PRNGKey(0)).compile().as_text()
    gathers = [ln for ln in txt.splitlines()
               if "all-gather" in ln and "= " in ln]
    u8 = [ln for ln in gathers if re.search(r"\bu8\[", ln)]
    f32_weight = [ln for ln in gathers if "f32[32,64]" in ln or
                  "f32[1,32,64]" in ln]
    assert u8, "expected a u8 all-gather on the wire"
    assert not f32_weight, "weights must not cross the wire in f32"
