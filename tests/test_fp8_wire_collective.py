"""FP8-wire federated collective: correctness + actual u8 payload on the wire.

The collective uses the flat-buffer codec (core/wire.py): ONE uint8 payload
per silo for the whole model, ONE all-gather moving u8 — not a per-tensor
collective, and never f32 weights.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import compression, wire
from repro.core.qat import alpha_like


def _params():
    w = jax.random.normal(jax.random.PRNGKey(0), (32, 64))
    return {"w": w, "w_qa": alpha_like(w), "b": jnp.ones((64,))}


def test_fp8_wire_mean_unbiased_single_device():
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()

    fn = jax.jit(shard_map(
        lambda p, k: compression.fp8_wire_allreduce_mean(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    ))
    acc = np.zeros(params["w"].shape, np.float64)
    n = 400
    for i in range(n):
        acc += np.asarray(fn(params, jax.random.PRNGKey(i))["w"])
    # Monte-Carlo error of the element mean is ~ grid_step / (2 sqrt(n));
    # the max over 2048 elements sits a few sigma out, hence the headroom.
    bias = np.abs(acc / n - np.asarray(params["w"])).max()
    assert bias < 2.5e-2, bias
    out = fn(params, jax.random.PRNGKey(0))
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(params["b"]))


def test_fp8_wire_collective_moves_uint8():
    """The lowered collective must carry u8, not f32 — the 4x is real."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()
    fn = shard_map(
        lambda p, k: compression.fp8_wire_allreduce_mean(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    )
    txt = jax.jit(fn).lower(params, jax.random.PRNGKey(0)).compile().as_text()
    # collective *op* lines only (consumers referencing the gather as an
    # operand don't count)
    gathers = [ln for ln in txt.splitlines()
               if re.search(r"=\s*\S*\s*all-gather(-start)?\(", ln)]
    assert gathers, "expected an all-gather in the lowering"
    u8 = [ln for ln in gathers if re.search(r"=\s*u8\[", ln)]
    f32 = [ln for ln in gathers if re.search(r"=\s*f32\[", ln)]
    assert u8, "expected a u8 all-gather on the wire"
    assert not f32, f"weights must not cross the wire in f32: {f32}"


def test_fp8_wire_allgather_stacks_silo_trees():
    """The gather variant must return stacked per-silo trees whose mean is
    the allreduce_mean result — same wire, aggregator-shaped output."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()
    key = jax.random.PRNGKey(4)
    gathered = jax.jit(shard_map(
        lambda p, k: compression.fp8_wire_allgather(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    ))(params, key)
    reduced = jax.jit(shard_map(
        lambda p, k: compression.fp8_wire_allreduce_mean(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    ))(params, key)
    assert gathered["w"].shape == (1,) + params["w"].shape
    np.testing.assert_allclose(
        np.asarray(jnp.mean(gathered["w"], axis=0)),
        np.asarray(reduced["w"]), rtol=0, atol=1e-6,
    )


def test_make_comm_round_with_stateful_aggregator():
    """make_comm_round(aggregator=FedAvgM) must thread server momentum
    through the round boundary: state nonzero after one boundary and the
    collective still moves u8."""
    from repro.core.engine import FedAvgM
    from repro.launch.steps import comm_round_state, make_comm_round
    from repro.core.qat import QATConfig

    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()
    agg = FedAvgM(lr=1.0, momentum=0.9)
    comm_state = comm_round_state(agg, params)
    fn = make_comm_round(mesh, P(), ("pod",), QATConfig(),
                         mode="rand", wire="fp8", aggregator=agg,
                         state_specs=P())
    new_params, new_state = jax.jit(fn)(params, comm_state,
                                        jax.random.PRNGKey(0))
    assert jax.tree.structure(new_params) == jax.tree.structure(params)
    assert any(bool(jnp.any(x != 0))
               for x in jax.tree.leaves(new_state["opt"])), \
        "server momentum stayed zero across the boundary"
    # the threaded baseline must be the NEW global model (next round's
    # pseudo-gradient anchor), identical on every silo
    np.testing.assert_array_equal(np.asarray(new_state["prev"]["w"]),
                                  np.asarray(new_params["w"]))
    txt = jax.jit(fn).lower(params, comm_state,
                            jax.random.PRNGKey(0)).compile().as_text()
    u8_gathers = [ln for ln in txt.splitlines()
                  if re.search(r"=\s*u8\[", ln)
                  and re.search(r"all-gather(-start)?\(", ln)]
    assert u8_gathers, "aggregator path lost the u8 wire"


def test_make_comm_round_partial_quorum():
    """make_comm_round(partial=True): the boundary takes a replicated
    alive mask — all-alive matches the non-partial round bitwise, an
    all-dead (below-quorum) round passes params AND aggregator state
    through unchanged, and partial=True without an aggregator raises."""
    from repro.core.engine import FedAvgM
    from repro.core.qat import QATConfig
    from repro.launch.steps import comm_round_state, make_comm_round

    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    params = _params()

    def build(**kw):
        agg = FedAvgM(lr=1.0, momentum=0.9)
        return make_comm_round(mesh, P(), ("pod",), QATConfig(),
                               mode="rand", wire="fp8", aggregator=agg,
                               state_specs=P(), **kw), \
            comm_round_state(agg, params)

    key = jax.random.PRNGKey(0)
    fn_ref, st_ref = build()
    ref_params, _ = jax.jit(fn_ref)(params, st_ref, key)

    fn, st = build(partial=True, min_quorum=1)
    alive = jnp.ones((1,), bool)
    new_params, new_state = jax.jit(fn)(params, st, key, alive)
    np.testing.assert_array_equal(np.asarray(new_params["w"]),
                                  np.asarray(ref_params["w"]),
                                  err_msg="all-alive partial != full round")

    dead_params, dead_state = jax.jit(fn)(params, st, key,
                                          jnp.zeros((1,), bool))
    np.testing.assert_array_equal(np.asarray(dead_params["w"]),
                                  np.asarray(st["prev"]["w"]),
                                  err_msg="below-quorum round moved params")
    assert all(not bool(jnp.any(x != 0))
               for x in jax.tree.leaves(dead_state["opt"])), \
        "below-quorum round moved aggregator state"
    np.testing.assert_array_equal(np.asarray(dead_state["prev"]["w"]),
                                  np.asarray(st["prev"]["w"]))

    with pytest.raises(ValueError, match="partial"):
        make_comm_round(mesh, P(), ("pod",), QATConfig(), mode="rand",
                        wire="fp8", partial=True)


def test_fp8_wire_single_collective_for_whole_model():
    """Flat codec collapses O(n_tensors) collectives into exactly one."""
    mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
    w2 = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
    params = _params()
    params["w2"], params["w2_qa"] = w2, alpha_like(w2)
    fn = shard_map(
        lambda p, k: compression.fp8_wire_allreduce_mean(p, k, ("pod",)),
        mesh=mesh, in_specs=(P(), P()), out_specs=P(), check_rep=False,
    )
    txt = jax.jit(fn).lower(params, jax.random.PRNGKey(0)).compile().as_text()
    u8_gathers = [ln for ln in txt.splitlines()
                  if re.search(r"=\s*u8\[", ln)
                  and re.search(r"all-gather(-start)?\(", ln)]
    assert len(u8_gathers) == 1, u8_gathers
    spec = wire.make_wire_spec(params)
    assert spec.total == 32 * 64 + 16 * 16
    # the gathered buffer is exactly 1 byte per quantized element
    assert any(f"u8[1,{spec.total}]" in ln for ln in u8_gathers), u8_gathers
