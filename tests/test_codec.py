"""WireCodec API tests (core/codec.py): packed sub-byte wire, delta
encoding, per-round schedules, registry/legacy-shim equivalence, and the
exact static==traced byte-accounting contract per codec.

These are the hypothesis-less twins of the property suite in
``test_properties.py`` (the container may lack hypothesis): the same
invariants, driven over a fixed grid of ragged pytrees instead of
generated ones, so the codec contract is enforced by plain ``pytest`` in
every lane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.core import codec as codec_lib, fp8, metrics, wire
from repro.core.codec import (
    CodecSchedule,
    DeltaCodec,
    Fp32Codec,
    Fp8Codec,
    PackedFpCodec,
    codec_for,
    get_codec,
)
from repro.core.engine import FedConfig, RoundEngine, WireLink
from repro.core.fp8 import E4M3, E5M2, FP4_E2M1, FP4_E3M0
from repro.core.qat import QATConfig, alpha_like, clip_value_mask, \
    weight_decay_mask
from repro.models import small


def _tree(seed: int = 0):
    """Ragged param-like pytree: odd shapes straddling the LANE width, a
    stacked-alpha slab, and FP32 riders."""
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    w0 = jax.random.normal(ks[0], (7, 131)) * 2.0          # odd total (917)
    w1 = jax.random.normal(ks[1], (3, 1025))               # straddles LANE
    slab = jax.random.normal(ks[2], (2, 5, 33))            # stacked alpha
    return {
        "w0": w0, "w0_qa": alpha_like(w0),
        "w1": w1, "w1_qa": alpha_like(w1),
        "slab": slab, "slab_qa": alpha_like(slab, stacked=True),
        "b": jax.random.normal(ks[3], (13,)),
    }


PACKED = [PackedFpCodec(FP4_E2M1, "rand"), PackedFpCodec(FP4_E2M1, "det"),
          PackedFpCodec(FP4_E3M0, "rand")]


@pytest.mark.parametrize("codec", PACKED, ids=lambda c: c.tag)
def test_packed_exact_payload_bytes(codec):
    """Sub-byte payloads are EXACTLY ceil(n * bits / 8) per leaf — ragged
    and stacked-alpha leaves included — and payload_nbytes counts codes +
    4 bytes per FP32 rider element."""
    params = _tree()
    spec = wire.make_wire_spec(params)
    k = 8 // codec.fmt.bits
    expect = sum(-(-v.size // k) for n, v in params.items()
                 if not n.endswith("_qa") and v.ndim >= 2)
    payload = codec.encode(params, spec, jax.random.PRNGKey(1))
    assert payload["codes"].dtype == jnp.uint8
    assert payload["codes"].shape == (expect,)
    assert codec.code_nbytes(spec) == expect
    assert codec.payload_nbytes(spec) == expect + 4 * spec.n_other_elems
    # FP4 is exactly half the FP8 codes for even-size leaves, ceil for odd
    leaf_sizes = [v.size for n, v in params.items()
                  if not n.endswith("_qa") and v.ndim >= 2]
    assert codec.code_nbytes(spec) == sum(-(-s // 2) for s in leaf_sizes)


@pytest.mark.parametrize("codec", PACKED, ids=lambda c: c.tag)
def test_packed_decode_encode_fixed_point(codec):
    """decode∘encode is a fixed point: re-encoding the decoded tree (fresh
    key!) reproduces the codes AND values bitwise in det and rand modes —
    grid points straddle no bin."""
    params = _tree()
    spec = wire.make_wire_spec(params)
    p1 = codec.encode(params, spec, jax.random.PRNGKey(1))
    once = codec.decode(p1, spec)
    p2 = codec.encode(once, spec, jax.random.PRNGKey(99))
    np.testing.assert_array_equal(np.asarray(p1["codes"]),
                                  np.asarray(p2["codes"]))
    twice = codec.decode(p2, spec)
    for a, b in zip(jax.tree.leaves(once), jax.tree.leaves(twice)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("codec", PACKED, ids=lambda c: c.tag)
def test_packed_grid_membership_and_riders(codec):
    """Decoded per-tensor-alpha leaves land on the sub-byte format's grid
    (the SAME parametric grid as FP8 at (exp, mant)); riders — clip values
    and sub-2D leaves — cross the wire bitwise."""
    params = _tree()
    spec = wire.make_wire_spec(params)
    payload = codec.encode(params, spec, jax.random.PRNGKey(2))
    out = codec.decode(payload, spec)
    for name, v in out.items():
        if name.endswith("_qa") or v.ndim < 2:
            np.testing.assert_array_equal(
                np.asarray(v), np.asarray(params[name]),
                err_msg=f"rider {name} changed")
            continue
        if params[name + "_qa"].size != 1:
            continue
        alpha = float(params[name + "_qa"])
        grid = fp8.quantization_grid(alpha, codec.fmt)
        full = np.concatenate([-grid[::-1], grid])
        arr = np.asarray(v).ravel()
        dist = np.min(np.abs(arr[:, None] - full[None, :]), axis=1)
        assert dist.max() < 1e-5 * max(alpha, 1.0), name


@pytest.mark.parametrize("codec", PACKED, ids=lambda c: c.tag)
def test_packed_fake_quant_matches_wire(codec):
    """The fused fake-quant transit observes what a payload receiver
    decodes (same key, same grid point, 1 f32 ULP at clip scale)."""
    params = _tree()
    spec = wire.make_wire_spec(params)
    key = jax.random.PRNGKey(3)
    via_wire = codec.decode(codec.encode(params, spec, key), spec)
    fused = codec.fake_quant(params, spec, key)
    for name in via_wire:
        a, b = np.asarray(via_wire[name]), np.asarray(fused[name])
        if name.endswith("_qa") or a.ndim < 2:
            np.testing.assert_array_equal(a, b, err_msg=name)
            continue
        alpha = float(np.max(np.asarray(params[name + "_qa"])))
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=4e-7 * alpha,
                                   err_msg=name)


def test_packed_codes_fit_sub_byte_fields():
    """Every 4-bit code pair uses only its own nibble (no cross-element
    bit bleed): unfolding the payload reproduces codes < 2^bits."""
    from repro.kernels.fp8_quant import unfold_codes

    params = _tree()
    spec = wire.make_wire_spec(params)
    codec = PackedFpCodec(FP4_E2M1, "rand")
    payload = codec.encode(params, spec, jax.random.PRNGKey(4))
    codes = np.asarray(unfold_codes(
        jnp.asarray(payload["codes"])[None, :], codec.fmt
    ))
    assert codes.max() < 2 ** codec.fmt.bits


# ---------------------------------------------------------------------------
# DeltaCodec
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "inner", [Fp8Codec(E4M3, "rand"), PackedFpCodec(FP4_E2M1, "rand")],
    ids=lambda c: c.tag)
def test_delta_roundtrip_error_scales_with_residual(inner):
    """Transmitting the residual quantizes on the RESIDUAL's grid: for a
    small update the absolute error is far below the plain codec's (whose
    grid spans the whole weight range), at (<=) the same byte count."""
    params = _tree()
    spec = wire.make_wire_spec(params)
    eps = 1e-3
    ref = {n: (v - eps if not n.endswith("_qa") and v.ndim >= 2 else v)
           for n, v in params.items()}
    delta = DeltaCodec(inner)
    out_d = delta.decode(
        delta.encode(params, spec, jax.random.PRNGKey(5), ref=ref),
        spec, ref=ref)
    out_p = inner.decode(
        inner.encode(params, spec, jax.random.PRNGKey(5)), spec)
    for n, v in params.items():
        if n.endswith("_qa") or v.ndim < 2:
            np.testing.assert_array_equal(np.asarray(out_d[n]),
                                          np.asarray(v), err_msg=n)
            continue
        err_d = np.max(np.abs(np.asarray(out_d[n]) - np.asarray(v)))
        err_p = np.max(np.abs(np.asarray(out_p[n]) - np.asarray(v)))
        # residual grid spacing ~ eps vs weight grid spacing ~ alpha
        assert err_d <= eps, (n, err_d)
        assert err_d < err_p / 10, (n, err_d, err_p)
    assert delta.code_nbytes(spec) == inner.code_nbytes(spec)
    assert delta.payload_nbytes(spec) == (
        inner.payload_nbytes(spec) + 4 * len(spec.q_slots))


def test_delta_unbiased():
    """E[decode(encode(w))] == w with a stochastic inner rounding — SR of
    the delta preserves Lemma 3's unbiasedness (the fresh per-leaf clip
    value max|residual| guarantees no clipping)."""
    params = _tree(seed=7)
    spec = wire.make_wire_spec(params)
    ref = {n: (v * 0.98 if not n.endswith("_qa") and v.ndim >= 2 else v)
           for n, v in params.items()}
    delta = DeltaCodec(Fp8Codec(E4M3, "rand"))
    fq = jax.jit(lambda k: delta.fake_quant(params, spec, k, ref=ref))
    n_keys = 400
    acc = np.zeros_like(np.asarray(params["w0"]))
    for i in range(n_keys):
        acc += np.asarray(fq(jax.random.PRNGKey(1000 + i))["w0"])
    mean = acc / n_keys
    resid_scale = float(np.max(np.abs(
        np.asarray(params["w0"]) - np.asarray(ref["w0"]))))
    # bias of an unbiased SR estimate: ~ S/sqrt(N) with S the bin size
    bias = np.abs(mean - np.asarray(params["w0"])).mean()
    assert bias < 5 * resid_scale / np.sqrt(n_keys), (bias, resid_scale)


def test_delta_requires_ref_and_rejects_downlink():
    params = _tree()
    spec = wire.make_wire_spec(params)
    delta = get_codec("delta:e4m3")
    assert isinstance(delta, DeltaCodec)
    with pytest.raises(ValueError, match="reference"):
        delta.encode(params, spec, jax.random.PRNGKey(0))
    with pytest.raises(ValueError, match="downlink"):
        WireLink(down_codec="delta:e4m3")


# ---------------------------------------------------------------------------
# Registry / legacy-shim resolution
# ---------------------------------------------------------------------------


def test_registry_and_shim():
    assert get_codec("e4m3") == Fp8Codec(E4M3, "rand")
    assert get_codec("e5m2_det") == Fp8Codec(E5M2, "det")
    assert get_codec("fp4") == PackedFpCodec(FP4_E2M1, "rand")
    assert get_codec("delta:fp4_e3m0").inner == PackedFpCodec(FP4_E3M0,
                                                              "rand")
    assert isinstance(get_codec("none"), Fp32Codec)
    with pytest.raises(KeyError, match="unknown codec"):
        get_codec("e9m9")
    # the legacy-knob deprecation map
    assert codec_for(E4M3, "rand") == get_codec("e4m3")
    assert codec_for(E5M2, "det") == get_codec("e5m2_det")
    assert codec_for(E4M3, "none") == Fp32Codec()
    assert codec_for(FP4_E2M1, "rand") == get_codec("fp4")
    # codec objects pass through
    sched = CodecSchedule(("e5m2", "fp4"), (3,))
    assert get_codec(sched) is sched


def test_schedule_validation():
    with pytest.raises(ValueError, match="boundaries"):
        CodecSchedule(("e4m3", "e5m2"), ())
    with pytest.raises(ValueError, match="increase"):
        CodecSchedule(("e4m3", "e5m2", "fp4"), (5, 5))
    with pytest.raises(ValueError, match="grid codecs"):
        CodecSchedule(("e4m3", "fp32"), (2,))
    s = CodecSchedule(("e5m2", "e4m3", "fp4"), (2, 5))
    assert [s.at(r).tag for r in (0, 1, 2, 4, 5, 9)] == [
        "e5m2", "e5m2", "e4m3", "e4m3", "fp4_e2m1", "fp4_e2m1"]
    assert [int(s.phase(jnp.int32(r))) for r in (0, 2, 5)] == [0, 1, 2]


def test_legacy_knobs_resolve_to_codecs():
    cfg = FedConfig(comm_mode="det", fmt=E5M2)
    assert cfg.resolved_down_codec == Fp8Codec(E5M2, "det")
    cfg = FedConfig(comm_mode="rand", down_mode="none", up_fmt=E5M2)
    assert isinstance(cfg.resolved_down_codec, Fp32Codec)
    assert cfg.resolved_up_codec == Fp8Codec(E5M2, "rand")
    # codec knobs win over legacy knobs; schedule wins over both
    cfg = FedConfig(comm_mode="det", down_codec="fp4")
    assert cfg.resolved_down_codec == PackedFpCodec(FP4_E2M1, "rand")
    sched = CodecSchedule(("e4m3", "fp4"), (2,))
    cfg = FedConfig(down_codec="fp4", codec_schedule=sched)
    assert cfg.resolved_down_codec is sched


def test_wirelink_legacy_kwargs_bit_identical_to_codec_objects():
    """A link built from legacy (fmt, mode) kwargs and one built from the
    resolved codec objects run the SAME leg ops — bitwise."""
    params = _tree()
    spec = wire.make_wire_spec(params)
    legacy = WireLink(down_fmt=E4M3, up_fmt=E5M2,
                      down_mode="rand", up_mode="det")
    explicit = WireLink(down_codec=Fp8Codec(E4M3, "rand"),
                        up_codec=Fp8Codec(E5M2, "det"))
    k = jax.random.PRNGKey(11)
    a = legacy.down(params, spec, k)
    b = explicit.down(params, spec, k)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    stacked = jax.tree.map(lambda x: jnp.stack([x, x * 1.01]), params)
    a = legacy.up(stacked, spec, k, 2)
    b = explicit.up(stacked, spec, k, 2)
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    assert legacy.down_bytes(spec) == explicit.down_bytes(spec)
    assert legacy.up_bytes(spec) == explicit.up_bytes(spec)


# ---------------------------------------------------------------------------
# End-to-end: engine/FedSim with codec links (static == traced bytes)
# ---------------------------------------------------------------------------


def _sim(cfg):
    from repro.core.fedsim import FedSim

    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=8, n_classes=4)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    k = cfg.n_clients
    cx = jax.random.normal(jax.random.PRNGKey(1), (k, 16, 8))
    cy = jax.random.randint(jax.random.PRNGKey(2), (k, 16), 0, 4)
    return FedSim(params, loss, apply, opt, cfg, cx, cy,
                  jnp.full((k,), 16.0)), params


_BASE = dict(n_clients=4, participation=1.0, local_steps=2, batch_size=8,
             qat=QATConfig())

CODEC_VARIANTS = [
    ("fp4_both", dict(down_codec="fp4", up_codec="fp4")),
    ("fp4_e3m0_det", dict(down_codec="fp4_e3m0_det",
                          up_codec="fp4_e3m0_det")),
    ("delta_up", dict(up_codec="delta:e4m3")),
    ("delta_fp4_up", dict(down_codec="fp4", up_codec="delta:fp4_e2m1")),
]


@pytest.mark.parametrize("kwargs", [v[1] for v in CODEC_VARIANTS],
                         ids=[v[0] for v in CODEC_VARIANTS])
def test_codec_static_equals_traced_bytes(kwargs):
    cfg = FedConfig(**_BASE, **kwargs)
    sim, params = _sim(cfg)
    _, m = sim._round(sim.state, sim.client_data, sim.client_labels,
                      sim.nk, jax.random.PRNGKey(0))
    static = metrics.round_bytes_for(params, cfg)
    assert static == sim.bytes_per_round
    assert int(m["wire_bytes"]) == static, (int(m["wire_bytes"]), static)
    hist = sim.run(2, jax.random.PRNGKey(6),
                   eval_data=(jax.random.normal(jax.random.PRNGKey(4),
                                                (24, 8)),
                              jnp.zeros((24,), jnp.int32)),
                   eval_every=1)
    assert hist.cumulative_bytes == [static, 2 * static]


def test_fp4_halves_quantized_leg_payload():
    """Acceptance: PackedFpCodec FP4 halves the quantized-leg payload (the
    codes buffer exactly; riders ride FP32 in both) vs the FP8 wire."""
    init, _ = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=8, n_classes=4)
    spec = wire.make_wire_spec(params)
    fp8_c, fp4_c = get_codec("e4m3"), get_codec("fp4")
    # mlp leaves are even-sized -> exactly half
    assert fp4_c.code_nbytes(spec) * 2 == fp8_c.code_nbytes(spec)
    assert (fp4_c.payload_nbytes(spec) ==
            fp8_c.payload_nbytes(spec) - fp8_c.code_nbytes(spec) // 2)
    cfg8 = FedConfig(**_BASE)
    cfg4 = FedConfig(**_BASE, down_codec="fp4", up_codec="fp4")
    b8 = metrics.round_bytes_for(params, cfg8)
    b4 = metrics.round_bytes_for(params, cfg4)
    assert b4 < b8
    assert b8 - b4 == cfg8.clients_per_round * fp8_c.code_nbytes(spec)


def test_schedule_end_to_end_per_round_bytes_and_counter():
    """A CodecSchedule resolves in-jit from the round-index operand: the
    traced wire_bytes switches at the boundaries, matches the static
    per-round accounting, ServerState.round threads, and FedSim charges
    the per-round (not round-0) bytes."""
    sched = CodecSchedule(("e5m2", "e4m3", "fp4"), (1, 3))
    cfg = FedConfig(**_BASE, codec_schedule=sched)
    sim, params = _sim(cfg)
    assert sim.engine.scheduled
    st = sim.state
    assert int(st.round) == 0
    seen = []
    for r in range(4):
        st, m = sim._round(st, sim.client_data, sim.client_labels, sim.nk,
                           jax.random.PRNGKey(r))
        seen.append(int(m["wire_bytes"]))
        assert seen[-1] == metrics.round_bytes_for(params, cfg, r), r
        assert seen[-1] == sim.engine.round_bytes(params, r)
    assert int(st.round) == 4
    # phases: e5m2 (r=0) == e4m3 (r=1,2, same byte count) > fp4 (r>=3)
    assert seen[0] == seen[1] == seen[2] > seen[3]
    hist = sim.run(4, jax.random.PRNGKey(9),
                   eval_data=(jax.random.normal(jax.random.PRNGKey(4),
                                                (24, 8)),
                              jnp.zeros((24,), jnp.int32)),
                   eval_every=1)
    assert hist.cumulative_bytes == list(np.cumsum(seen))


def test_schedule_rejected_by_stateless_shim():
    from repro.core.fedavg import make_round

    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=8, n_classes=4)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05)
    cfg = FedConfig(**_BASE,
                    codec_schedule=CodecSchedule(("e4m3", "fp4"), (2,)))
    with pytest.raises(ValueError, match="CodecSchedule"):
        make_round(loss, opt, cfg)


def test_unscheduled_state_has_no_round_leaf():
    """Non-scheduled configs keep the exact pre-codec ServerState pytree
    (round == () adds no leaf — checkpoints and shims unchanged)."""
    cfg = FedConfig(**_BASE)
    sim, _ = _sim(cfg)
    assert sim.state.round == ()
    n_leaves = len(jax.tree.leaves(sim.state))
    assert n_leaves == len(jax.tree.leaves(sim.state.params))


@pytest.mark.parametrize("codec_name", ["fp4", "delta:e4m3"])
def test_make_comm_round_codec_wire(codec_name):
    """The production round boundary takes a codec: the collective still
    moves a single u8 payload per silo (half-size for FP4), and a delta
    codec's reference is the threaded previous global model."""
    import re

    from jax.sharding import Mesh, PartitionSpec as P

    from repro.core.engine import FedAvgM
    from repro.launch.steps import comm_round_state, make_comm_round

    init, _ = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=8, n_classes=4)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("pod",))
    agg = FedAvgM(lr=1.0, momentum=0.9)
    comm_state = comm_round_state(agg, params)
    fn = make_comm_round(mesh, P(), ("pod",), QATConfig(),
                         aggregator=agg, state_specs=P(),
                         codec=codec_name)
    new_params, new_state = jax.jit(fn)(params, comm_state,
                                        jax.random.PRNGKey(0))
    assert jax.tree.structure(new_params) == jax.tree.structure(params)
    txt = jax.jit(fn).lower(params, comm_state,
                            jax.random.PRNGKey(0)).compile().as_text()
    u8 = [ln for ln in txt.splitlines()
          if re.search(r"=\s*u8\[", ln)
          and re.search(r"all-gather(-start)?\(", ln)]
    assert u8, f"{codec_name}: boundary lost the compressed wire"
    spec = wire.make_wire_spec(params)
    expect = get_codec(codec_name).code_nbytes(spec)
    assert any(re.search(rf"u8\[1,{expect}\]", ln) for ln in u8), (
        expect, u8)


# ---------------------------------------------------------------------------
# Sharded executor lane (multi-device): codecs through the fused u8 gather
# ---------------------------------------------------------------------------


SHARDED_VARIANTS = [
    ("fp4", dict(down_codec="fp4", up_codec="fp4")),
    ("delta_up", dict(up_codec="delta:e4m3")),
    ("sched", dict(codec_schedule=CodecSchedule(("e5m2", "fp4"), (1,)))),
]


@pytest.mark.parametrize("kwargs", [v[1] for v in SHARDED_VARIANTS],
                         ids=[v[0] for v in SHARDED_VARIANTS])
def test_sharded_codec_rounds_bit_identical_to_local(virtual_devices,
                                                     kwargs):
    """ShardedExecutor rounds with packed / delta / scheduled uplinks are
    bitwise equal to the local round under the same key, for multiple
    rounds (schedule phases included) — the one-payload-all-gather
    contract holds for every codec."""
    from repro.launch.mesh import make_client_mesh

    init, apply = small.REGISTRY["mlp"]
    params = init(jax.random.PRNGKey(0), d_in=8, n_classes=4)
    loss = small.make_loss(apply)
    opt = optim.sgd(0.05, wd_mask=weight_decay_mask(params),
                    trust_mask=clip_value_mask(params))
    K = 8
    cx = jax.random.normal(jax.random.PRNGKey(1), (K, 16, 8))
    cy = jax.random.randint(jax.random.PRNGKey(2), (K, 16), 0, 4)
    nk = jnp.full((K,), 16.0)
    base = dict(n_clients=K, participation=1.0, local_steps=2,
                batch_size=8, qat=QATConfig())
    mesh = make_client_mesh(4)
    e_sh = RoundEngine(loss, opt, FedConfig(mesh=mesh, **base, **kwargs))
    e_lo = RoundEngine(loss, opt, FedConfig(**base, **kwargs))
    st_s, st_l = e_sh.init(params), e_lo.init(params)
    rf_s, rf_l = jax.jit(e_sh.round_fn), jax.jit(e_lo.round_fn)
    for r in range(3):
        key = jax.random.PRNGKey(100 + r)
        st_s, ms = rf_s(st_s, cx, cy, nk, key)
        st_l, ml = rf_l(st_l, cx, cy, nk, key)
        assert int(ms["wire_bytes"]) == int(ml["wire_bytes"]), r
        for a, b in zip(jax.tree.leaves(st_s.params),
                        jax.tree.leaves(st_l.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"round {r}")
